//! The PPO training loop — as a two-speed **experience pipeline**:
//!
//! - `pipeline.depth = 0` (default): the serial loop — rollout → GAE →
//!   minibatched PPO epochs, one after another on the caller thread. With
//!   `minibatches = 1` this is bit-identical to the pre-pipeline trainer
//!   (pinned by `tests/pipeline.rs`).
//! - `pipeline.depth = d ≥ 1`: a collector thread owns the [`VecEnv`] and
//!   fills one of `d + 1` rotating [`RolloutBuffer`] segments, inferring
//!   off an epoch-versioned [`ParamSnapshot`], while the learner (this
//!   thread) consumes completed segments — GAE plus shuffled-minibatch
//!   PPO epochs — and publishes fresh parameters. Simulation and
//!   optimization overlap; each side's stall time is reported so the
//!   depth × minibatches balance is tunable from the logs.
//!
//! Everything runs through the [`PolicyBackend`] abstraction, so the same
//! loop drives the pure-Rust [`NativeBackend`] (default) and the AOT/PJRT
//! path (`pjrt` feature).

use super::pipeline::{collector_loop, Segment};
use super::rollout::{collect_rollout, EpisodeLog, RolloutBuffer};
use super::{Checkpoint, EvalReport, TrainConfig, TrainReport};
use crate::backend::{AdamState, MinibatchScratch, NativeBackend, PolicyBackend, TrainBatch};
use crate::policy::{ParamSnapshot, Policy, PolicySpec};
use crate::runspec::RunSpec;
use crate::sync::queue;
use crate::util::rng::Rng;
use crate::util::seed::SeedPlan;
use crate::util::timer::{SpsCounter, Timer};
use crate::vector::{VecEnv, VecSpec};
use crate::wrappers::EnvSpec;
use anyhow::Result;
use std::io::Write as _;

/// Lazily-opened `metrics.csv` sink. Nothing on disk is touched until
/// the first row is written, so trainers that never train (e.g.
/// `puffer eval <ckpt>` rebuilding from an embedded RunSpec) leave the
/// run dir untouched. The truncate-vs-append decision is made at first
/// write: a fresh run starts a clean file; a restored trainer
/// ([`Trainer::restore`]) appends, continuing the original run's curve
/// instead of erasing its history. The header is written only when the
/// file ends up empty.
struct MetricsSink {
    path: Option<String>,
    file: Option<std::fs::File>,
    /// Set by `restore()`: append instead of truncating.
    append: bool,
}

impl MetricsSink {
    fn new(run_dir: Option<&str>) -> Self {
        MetricsSink {
            path: run_dir.map(|dir| format!("{dir}/metrics.csv")),
            file: None,
            append: false,
        }
    }

    /// The open file, creating it on first use (`None` when the run has
    /// no directory).
    fn file(&mut self) -> Result<Option<&mut std::fs::File>> {
        if self.file.is_none() {
            let Some(path) = &self.path else {
                return Ok(None);
            };
            if let Some(dir) = std::path::Path::new(path).parent() {
                std::fs::create_dir_all(dir)?;
            }
            let mut f = if self.append {
                std::fs::OpenOptions::new().create(true).append(true).open(path)?
            } else {
                std::fs::File::create(path)?
            };
            if f.metadata()?.len() == 0 {
                writeln!(
                    f,
                    "global_step,sps,score,ep_return,ep_length,loss,pg_loss,v_loss,entropy,approx_kl,env_sps,learn_sps,stall_s"
                )?;
            }
            self.file = Some(f);
        }
        Ok(self.file.as_mut())
    }
}

/// Clean PuffeRL.
pub struct Trainer {
    cfg: TrainConfig,
    backend: Box<dyn PolicyBackend>,
    policy: Policy,
    venv: Box<dyn VecEnv>,
    buf: RolloutBuffer,
    log: EpisodeLog,
    spec_key: String,
    opt: AdamState,
    global_step: u64,
    metrics: MetricsSink,
    /// Live telemetry for `puffer ps` / `puffer top`: rewrites
    /// `<run_dir>/heartbeat.json` once per configured period (`None`
    /// when the run has no directory — nothing to watch).
    heartbeat: Option<crate::runs::HeartbeatWriter>,
    /// Per-stream seeds: [`SeedPlan::legacy`] for directly-configured
    /// trainers (bit-identical to the pre-RunSpec loop),
    /// [`SeedPlan::from_root`] for RunSpec-constructed ones.
    seeds: SeedPlan,
    /// The declarative spec this trainer was built from, when it was
    /// built through [`Trainer::from_run_spec`] — embedded in every
    /// checkpoint so `puffer resume` / `puffer eval` need zero flags.
    run_spec: Option<RunSpec>,
    /// Minibatch row-permutation stream (never consumed when
    /// `minibatches == 1`, keeping the full-batch path bit-identical to
    /// the pre-pipeline trainer).
    shuffle_rng: Rng,
    scratch: MinibatchScratch,
}

impl Trainer {
    /// The env + wrapper-chain spec this config describes — what every
    /// construction path (probe, backend, vectorizer) builds from.
    fn env_spec(cfg: &TrainConfig) -> EnvSpec {
        EnvSpec::new(cfg.env.as_str()).with_wrappers(cfg.wrappers.iter().cloned())
    }

    /// The policy architecture this config trains: the explicit
    /// [`TrainConfig::policy`] spec, or the env's default.
    fn policy_spec(cfg: &TrainConfig) -> PolicySpec {
        cfg.policy
            .clone()
            .unwrap_or_else(|| PolicySpec::default_for(&cfg.env))
    }

    /// Train with the default pure-Rust [`NativeBackend`]: no artifacts,
    /// no Python, no native dependencies. The backend spec is sized from
    /// the *wrapped* env (stacking widens `obs_dim`) and resolved
    /// against its observation layout (per-leaf encoders), and its key
    /// embeds the wrapper chain plus any non-default architecture so
    /// checkpoints never cross chains or architectures silently.
    pub fn native(cfg: TrainConfig) -> Result<Self> {
        let seeds = SeedPlan::legacy(cfg.seed);
        Self::native_with(cfg, seeds, None)
    }

    /// Construct from a declarative [`RunSpec`] — the one-line
    /// experiment path. Differences from [`Trainer::native`]: the env,
    /// wrappers, policy, vectorization, and train settings all come from
    /// the spec; every RNG stream is derived from the single `run.seed`
    /// root via the documented split function
    /// ([`SeedPlan::from_root`]); and checkpoints embed the serialized
    /// spec, so `puffer resume <ckpt>` / `puffer eval <ckpt>` work with
    /// zero flags.
    pub fn from_run_spec(spec: &RunSpec) -> Result<Self> {
        let cfg = spec.train_config();
        let seeds = SeedPlan::from_root(spec.seed);
        Self::native_with(cfg, seeds, Some(spec.clone()))
    }

    fn native_with(cfg: TrainConfig, seeds: SeedPlan, run_spec: Option<RunSpec>) -> Result<Self> {
        let spec = Self::env_spec(&cfg);
        let probe = spec.build(0);
        let policy = Self::policy_spec(&cfg);
        let mut backend = NativeBackend::for_env_with_policy(&spec.key(), probe.as_ref(), &policy)?;
        backend.set_kernel_path(cfg.kernels);
        Self::build(cfg, Box::new(backend), probe, seeds, run_spec)
    }

    /// Train through the AOT/PJRT path (requires the `pjrt` feature and
    /// `make artifacts`).
    #[cfg(feature = "pjrt")]
    pub fn pjrt(cfg: TrainConfig, artifacts_dir: &str) -> Result<Self> {
        anyhow::ensure!(
            cfg.wrappers.is_empty(),
            "the pjrt backend executes AOT-compiled specs with fixed shapes; \
             wrapper chains are supported on the native backend only for now"
        );
        anyhow::ensure!(
            cfg.minibatches == 1,
            "the pjrt backend's train_step was AOT-lowered for the full \
             (horizon, batch_roll) segment; train.minibatches > 1 requires \
             the native backend"
        );
        anyhow::ensure!(
            cfg.norm_adv,
            "the pjrt backend's compiled train_step always normalizes \
             advantages; train.norm_adv=false requires the native backend"
        );
        if let Some(policy) = &cfg.policy {
            anyhow::ensure!(
                *policy == PolicySpec::default_for(&cfg.env),
                "the pjrt backend executes AOT-lowered default architectures \
                 only; the requested spec '{}' (train.policy.* / --policy.*) \
                 requires the native backend, which builds arbitrary \
                 PolicySpecs from the spec itself",
                policy.key()
            );
        }
        let key = crate::runtime::Manifest::spec_key_for_env(&cfg.env);
        let backend = crate::backend::PjrtBackend::new(artifacts_dir, &key)?;
        Self::with_backend(cfg, Box::new(backend))
    }

    /// Train with any [`PolicyBackend`].
    pub fn with_backend(cfg: TrainConfig, backend: Box<dyn PolicyBackend>) -> Result<Self> {
        let probe = Self::env_spec(&cfg).build(0);
        let seeds = SeedPlan::legacy(cfg.seed);
        Self::build(cfg, backend, probe, seeds, None)
    }

    fn build(
        cfg: TrainConfig,
        mut backend: Box<dyn PolicyBackend>,
        probe: Box<dyn crate::emulation::FlatEnv>,
        seeds: SeedPlan,
        run_spec: Option<RunSpec>,
    ) -> Result<Self> {
        let spec = backend.spec().clone();
        let spec_key = backend.key().to_string();

        // Contract check against the probe env: shape drift between the
        // backend spec and the Rust env fails loudly here.
        anyhow::ensure!(
            spec.obs_dim == probe.obs_layout().flat_len(),
            "spec '{spec_key}': obs_dim {} != env flat obs len {}",
            spec.obs_dim,
            probe.obs_layout().flat_len()
        );
        anyhow::ensure!(
            spec.act_dims == probe.action_dims(),
            "spec '{spec_key}': act_dims {:?} != env action dims {:?}",
            spec.act_dims,
            probe.action_dims()
        );
        anyhow::ensure!(
            spec.agents == probe.num_agents(),
            "spec '{spec_key}': agents {} != env num_agents {}",
            spec.agents,
            probe.num_agents()
        );
        drop(probe);

        let agents = spec.agents;
        anyhow::ensure!(
            spec.batch_roll % agents == 0,
            "batch_roll {} not divisible by agents {agents}",
            spec.batch_roll
        );
        anyhow::ensure!(
            cfg.minibatches >= 1 && spec.batch_roll % cfg.minibatches == 0,
            "train.minibatches {} must be >= 1 and divide batch_roll {} \
             (minibatches slice whole agent rows)",
            cfg.minibatches,
            spec.batch_roll
        );
        let num_envs = spec.batch_roll / agents;

        // Vectorizer: built through the declarative VecSpec from the
        // same EnvSpec as the probe, so the worker slabs use the wrapped
        // layout. Explicit `cfg.vec` wins; otherwise the legacy
        // num_workers/pool knobs map through the same spec type.
        let env_spec = Self::env_spec(&cfg);
        let vec_spec = match &cfg.vec {
            Some(v) => v.clone(),
            None => VecSpec::from_workers_pool(cfg.num_workers, cfg.pool),
        };
        let vec_spec = vec_spec.resolved(&env_spec, num_envs, cfg.run_dir.as_deref())?;
        let venv = vec_spec.build(&env_spec, num_envs, seeds.env)?;
        spec.ensure_trainable_batch(&vec_spec.to_string(), venv.batch_size())?;

        let policy = Policy::new(backend.as_mut(), seeds.policy)?;
        let buf = RolloutBuffer::new(
            spec.horizon,
            spec.batch_roll,
            spec.obs_dim,
            spec.act_dims.len(),
        );

        let metrics = MetricsSink::new(cfg.run_dir.as_deref());
        let heartbeat = cfg.run_dir.as_deref().map(|dir| {
            let period_s = run_spec
                .as_ref()
                .and_then(|s| s.runs.as_ref())
                .map(|r| r.heartbeat_s)
                .unwrap_or_else(|| crate::runs::RunsConfig::default().heartbeat_s);
            crate::runs::HeartbeatWriter::new(dir, period_s, cfg.total_steps)
        });
        let shuffle_rng = Rng::new(seeds.shuffle);
        Ok(Trainer {
            cfg,
            backend,
            policy,
            venv,
            buf,
            log: EpisodeLog::default(),
            spec_key,
            opt: AdamState::new(spec.n_params),
            global_step: 0,
            metrics,
            heartbeat,
            seeds,
            run_spec,
            shuffle_rng,
            scratch: MinibatchScratch::default(),
        })
    }

    pub fn policy(&self) -> &Policy {
        &self.policy
    }
    pub fn global_step(&self) -> u64 {
        self.global_step
    }
    /// The declarative spec this trainer was built from (only when
    /// constructed through [`Trainer::from_run_spec`]).
    pub fn run_spec(&self) -> Option<&RunSpec> {
        self.run_spec.as_ref()
    }

    /// Run the full training loop (serial or pipelined per
    /// [`TrainConfig::pipeline_depth`]).
    pub fn train(&mut self) -> Result<TrainReport> {
        // Test hook: the integration suite injects a deterministic child
        // failure (sweep panic isolation / registry `failed` records) by
        // naming a run-dir substring in this env var. Inert otherwise.
        if let Ok(needle) = std::env::var("PUFFER_TEST_TRAIN_PANIC") {
            if let Some(dir) = &self.cfg.run_dir {
                if !needle.is_empty() && dir.contains(&needle) {
                    panic!("PUFFER_TEST_TRAIN_PANIC: injected failure for {dir}");
                }
            }
        }
        // First beat before any stepping so even instant crashes leave a
        // heartbeat for `puffer ps` to date the attempt by.
        if let Some(hb) = self.heartbeat.as_mut() {
            hb.force(self.global_step, 0.0, 0.0, 0.0, None)?;
        }
        let report = if self.cfg.pipeline_depth == 0 {
            self.train_serial()?
        } else {
            self.train_pipelined()?
        };
        if let Some(dir) = &self.cfg.run_dir {
            std::fs::create_dir_all(dir)?;
            self.checkpoint().save(format!("{dir}/checkpoint.bin"))?;
        }
        // Final beat with the report's numbers so `ps` shows the finished
        // progress even if the registry transition races a reader.
        if let Some(hb) = self.heartbeat.as_mut() {
            hb.force(
                report.global_step,
                report.env_sps,
                report.learn_sps,
                report.collector_stall_s + report.learner_stall_s,
                report.mean_score,
            )?;
        }
        Ok(report)
    }

    /// The serial loop: collect a segment, then learn on it, on one
    /// thread. With `minibatches == 1` every operation — and therefore
    /// every parameter bit — matches the pre-pipeline trainer.
    fn train_serial(&mut self) -> Result<TrainReport> {
        let n = self.buf.segment_steps() as u64;
        let mut sps = SpsCounter::new();
        let mut tel = Telemetry::default();
        let mut last_metrics = [0.0f32; 5];
        let mut segment = 0u64;
        let mut score_curve = Vec::new();

        self.venv.async_reset(self.seeds.env);
        self.buf.mark_all_starts();
        self.policy.reset_all_state();

        while self.global_step < self.cfg.total_steps {
            // ---- Rollout ----
            let roll = Timer::start();
            let (policy, backend, venv, buf, log) = (
                &mut self.policy,
                &mut *self.backend,
                &mut *self.venv,
                &mut self.buf,
                &mut self.log,
            );
            collect_rollout(venv, buf, log, |obs, rows, done_rows| {
                // Zero recurrent state for rows whose episode just ended
                // *before* the forward pass on their fresh observations —
                // the LSTM state-reset discipline of paper §3.4.
                for &r in done_rows {
                    policy.reset_state(r);
                }
                policy.step(&mut *backend, obs, rows)
            })?;
            tel.env_active_s += roll.secs();
            self.global_step += n;
            sps.add(n);

            // ---- GAE + PPO epochs ----
            let lr = anneal_lr(&self.cfg, self.global_step, self.cfg.total_steps);
            let learn = Timer::start();
            last_metrics = learn_on_segment(
                &mut *self.backend,
                self.policy.params_mut(),
                &mut self.opt,
                &self.cfg,
                &mut self.shuffle_rng,
                &mut self.scratch,
                &self.buf,
                lr,
            )?;
            tel.learn_s += learn.secs();

            // ---- Logging ----
            segment += 1;
            if let Some(s) = self.log.mean_score(100) {
                score_curve.push((self.global_step, s));
            }
            log_segment(
                &self.cfg,
                &mut self.metrics,
                &mut self.heartbeat,
                self.global_step,
                sps.window(),
                sps.total(),
                &self.log,
                &last_metrics,
                segment,
                &tel,
            )?;
        }

        Ok(self.report(sps.overall(), sps.total(), &tel, last_metrics, score_curve))
    }

    /// The pipelined loop: a collector thread fills rotating segment
    /// buffers (inference off the latest published params) while this
    /// thread learns on completed segments and publishes updates.
    fn train_pipelined(&mut self) -> Result<TrainReport> {
        let depth = self.cfg.pipeline_depth;
        let spec = self.policy.spec().clone();
        let n = (spec.horizon * spec.batch_roll) as u64;
        let remaining = self.cfg.total_steps.saturating_sub(self.global_step);
        let segments_total = remaining.div_ceil(n);

        // Collector-side inference stack: a forked backend plus its own
        // policy (sampling RNG + recurrent state), reading the learner's
        // published weights — never its in-place-mutating buffer.
        let mut col_backend = self.backend.fork_for_rollout()?;
        let mut col_policy = Policy::new(col_backend.as_mut(), self.seeds.collector)?;
        col_policy.set_params(self.policy.params());
        let snapshot = ParamSnapshot::new(self.policy.params().to_vec());

        // depth + 1 buffers rotate collector → learner → collector; the
        // buffer pool, not the channel, is the back-pressure bound. The
        // trainer's own segment buffer is lent as pool slot 0 (the
        // collector rewrites the episode carry before every fill) and
        // re-created after the scope, so peak memory is depth + 1 segment
        // buffers instead of depth + 2.
        let (free_tx, free_rx) = queue::channel::<RolloutBuffer>(None);
        let (filled_tx, filled_rx) = queue::channel::<Result<Segment>>(Some(depth + 1));
        let lent = std::mem::replace(&mut self.buf, RolloutBuffer::new(0, 0, 0, 0));
        assert!(free_tx.send(lent).is_ok(), "free_rx alive until the scope");
        for _ in 0..depth {
            let buf = RolloutBuffer::new(
                spec.horizon,
                spec.batch_roll,
                spec.obs_dim,
                spec.act_dims.len(),
            );
            assert!(free_tx.send(buf).is_ok(), "free_rx alive until the scope");
        }

        let seed = self.seeds.env;
        let mut sps = SpsCounter::new();
        let mut tel = Telemetry::default();
        let mut last_metrics = [0.0f32; 5];
        let mut score_curve = Vec::new();

        let Trainer {
            cfg,
            backend,
            policy,
            venv,
            log,
            opt,
            global_step,
            metrics,
            heartbeat,
            shuffle_rng,
            scratch,
            ..
        } = self;

        // Reborrows handed to the spawned collector must be created out
        // here: scoped threads may only borrow data living outside the
        // scope closure.
        let venv_ref: &mut dyn VecEnv = &mut **venv;
        let col_policy_ref = &mut col_policy;
        let col_backend_ref = col_backend.as_mut();
        let snapshot_ref = &snapshot;

        let scope_result = std::thread::scope(|s| -> Result<()> {
            // Rebinding moves the learner-side endpoints *into* this
            // closure, so every exit path (success or `?`) drops them
            // here — unblocking a collector stuck on recv/send before
            // the scope's implicit join.
            let free_tx = free_tx;
            let filled_rx = filled_rx;
            let _collector = s.spawn(move || {
                collector_loop(
                    venv_ref,
                    col_policy_ref,
                    col_backend_ref,
                    snapshot_ref,
                    free_rx,
                    filled_tx,
                    segments_total,
                    seed,
                )
            });

            let mut segment = 0u64;
            while segment < segments_total {
                let wait = Timer::start();
                let msg = filled_rx.recv().ok_or_else(|| {
                    anyhow::anyhow!("collector thread exited before delivering all segments")
                })?;
                tel.learner_stall_s += wait.secs();
                let seg: Segment = msg?;
                // `segment` publishes have happened so far; the collector
                // inferred this segment with version `seg.version`.
                tel.max_staleness = tel.max_staleness.max(segment.saturating_sub(seg.version));
                log.merge(&seg.log);
                *global_step += seg.steps;
                sps.add(seg.steps);
                tel.env_active_s += seg.collect_s;
                tel.collector_stall_s += seg.stall_s;

                let lr = anneal_lr(cfg, *global_step, cfg.total_steps);
                let learn = Timer::start();
                last_metrics = learn_on_segment(
                    backend.as_mut(),
                    policy.params_mut(),
                    opt,
                    cfg,
                    shuffle_rng,
                    scratch,
                    &seg.buf,
                    lr,
                )?;
                tel.learn_s += learn.secs();
                snapshot.publish(policy.params());

                segment += 1;
                if let Some(sc) = log.mean_score(100) {
                    score_curve.push((*global_step, sc));
                }
                log_segment(
                    cfg,
                    metrics,
                    heartbeat,
                    *global_step,
                    sps.window(),
                    sps.total(),
                    log,
                    &last_metrics,
                    segment,
                    &tel,
                )?;
                // Recycle; the collector may already be done with its
                // quota, so a hung-up receiver is fine.
                let _ = free_tx.send(seg.buf);
            }
            Ok(())
        });

        // Re-create the lent segment buffer on every exit path (including
        // errors) so a later train() on this trainer — e.g. after
        // restore() rewinds global_step — finds a full-sized buffer.
        self.buf = RolloutBuffer::new(
            spec.horizon,
            spec.batch_roll,
            spec.obs_dim,
            spec.act_dims.len(),
        );
        scope_result?;

        Ok(self.report(sps.overall(), sps.total(), &tel, last_metrics, score_curve))
    }

    fn report(
        &self,
        sps: f64,
        steps: u64,
        tel: &Telemetry,
        last_metrics: [f32; 5],
        score_curve: Vec<(u64, f64)>,
    ) -> TrainReport {
        TrainReport {
            global_step: self.global_step,
            sps,
            env_sps: rate(steps, tel.env_active_s),
            learn_sps: rate(steps, tel.learn_s),
            collector_stall_s: tel.collector_stall_s,
            learner_stall_s: tel.learner_stall_s,
            max_param_staleness: tel.max_staleness,
            mean_score: self.log.mean_score(100),
            mean_return: self.log.mean_return(100),
            episodes: self.log.scores.len(),
            last_loss: last_metrics[0],
            score_curve,
        }
    }

    /// Evaluate the current policy (stochastic sampling, fresh envs) for
    /// `min_episodes` episodes.
    pub fn eval(&mut self, min_episodes: usize) -> Result<EvalReport> {
        let mut log = EpisodeLog::default();
        self.venv.async_reset(self.seeds.eval);
        self.policy.reset_all_state();
        let agents = self.venv.agents_per_env();
        let slots = self.venv.action_dims().len();
        let layout = self.venv.obs_layout().clone();
        let d = layout.flat_len();
        while log.scores.len() < min_episodes {
            let (raw_obs, env_ids, terms, truncs, infos) = {
                let b = self.venv.recv()?;
                (
                    b.obs.to_vec(),
                    b.env_ids.to_vec(),
                    b.terms.to_vec(),
                    b.truncs.to_vec(),
                    b.infos,
                )
            };
            log.absorb(&infos);
            let mut global_rows = Vec::new();
            for &e in &env_ids {
                for a in 0..agents {
                    global_rows.push(e * agents + a);
                }
            }
            let rows = global_rows.len();
            // Eval-side recurrent reset: done flags arrive with the batch;
            // rows whose episode just ended get fresh obs (auto-reset), so
            // their LSTM state must be zeroed before the forward pass —
            // the same discipline the training rollout applies.
            for (i, &g) in global_rows.iter().enumerate() {
                if terms[i] || truncs[i] {
                    self.policy.reset_state(g);
                }
            }
            let mut obs_f32 = vec![0.0; rows * d];
            for (i, row) in raw_obs.chunks_exact(layout.byte_len()).enumerate() {
                layout.row_to_f32(row, &mut obs_f32[i * d..(i + 1) * d]);
            }
            let out = self.policy.step(&mut *self.backend, &obs_f32, &global_rows)?;
            self.venv.send(&out.actions[..rows * slots])?;
        }
        Ok(EvalReport {
            episodes: log.scores.len(),
            mean_score: log.mean_score(usize::MAX),
            mean_return: log.mean_return(usize::MAX),
        })
    }

    /// Snapshot trainer state. When the trainer was built from a
    /// [`RunSpec`], the serialized spec rides along so `puffer resume` /
    /// `puffer eval` can reconstruct the whole experiment with zero
    /// flags. Specs that cannot serialize (custom base env,
    /// non-canonical wrapper chain) checkpoint without an embedded spec
    /// — such runs restore through the explicit API, matched by
    /// `spec_key` as always.
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            spec_key: self.spec_key.clone(),
            run_spec_json: self
                .run_spec
                .as_ref()
                .filter(|r| r.to_flat().is_ok())
                .map(|r| r.to_json().dump()),
            global_step: self.global_step,
            params: self.policy.params().to_vec(),
            adam_m: self.opt.m.clone(),
            adam_v: self.opt.v.clone(),
            adam_step: self.opt.step,
        }
    }

    /// Restore from a checkpoint (env spec, wrapper chain, and policy
    /// architecture must all match — they are the key).
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        if ck.spec_key != self.spec_key {
            // The key is `<env+wrappers>[#<arch>]`; name the mismatched
            // half so the fix (re-train, or match --policy.*) is obvious.
            let split = |k: &str| -> (String, String) {
                match k.split_once('#') {
                    Some((env, arch)) => (env.to_string(), arch.to_string()),
                    None => (k.to_string(), "default".to_string()),
                }
            };
            let (ck_env, ck_arch) = split(&ck.spec_key);
            let (my_env, my_arch) = split(&self.spec_key);
            if ck_env == my_env && ck_arch != my_arch {
                anyhow::bail!(
                    "checkpoint is for '{ck_env}' with policy architecture \
                     '{ck_arch}', but this trainer resolved architecture \
                     '{my_arch}' — parameter layouts differ across \
                     architectures; match the checkpoint's --policy.* spec \
                     or retrain"
                );
            }
            anyhow::bail!(
                "checkpoint is for '{}', trainer is '{}'",
                ck.spec_key,
                self.spec_key
            );
        }
        anyhow::ensure!(
            ck.params.len() == self.policy.spec().n_params,
            "checkpoint '{}' has {} params, this backend expects {} — was it \
             written by a backend with a different architecture (e.g. a \
             recurrent pjrt spec vs the feedforward native spec)?",
            ck.spec_key,
            ck.params.len(),
            self.policy.spec().n_params
        );
        anyhow::ensure!(
            ck.adam_m.len() == ck.params.len() && ck.adam_v.len() == ck.params.len(),
            "checkpoint optimizer state length does not match its params"
        );
        *self.policy.params_mut() = ck.params.clone();
        self.opt.m = ck.adam_m.clone();
        self.opt.v = ck.adam_v.clone();
        self.opt.step = ck.adam_step;
        self.global_step = ck.global_step;
        // This trainer now continues an earlier run: metrics must append
        // to that run's history, not truncate it (no-op if rows were
        // already written this session — the file is simply kept open).
        self.metrics.append = true;
        Ok(())
    }
}

/// Per-run wall-clock accounting (both trainer paths).
#[derive(Default)]
struct Telemetry {
    /// Collection time: env stepping + rollout inference.
    env_active_s: f64,
    /// Learning time: GAE + PPO epochs.
    learn_s: f64,
    collector_stall_s: f64,
    learner_stall_s: f64,
    /// Worst published-updates lag of any consumed segment's snapshot.
    max_staleness: u64,
}

fn rate(steps: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        0.0
    } else {
        steps as f64 / secs
    }
}

/// Annealed learning rate at `global_step` (the pre-pipeline formula,
/// evaluated after the segment's steps are added).
fn anneal_lr(cfg: &TrainConfig, global_step: u64, total_steps: u64) -> f32 {
    if cfg.anneal_lr {
        let frac = 1.0 - global_step as f32 / total_steps as f32;
        cfg.lr * frac.max(0.05)
    } else {
        cfg.lr
    }
}

/// Learner half shared by both paths: GAE over the full segment, then
/// `epochs × minibatches` PPO updates. With `minibatches == 1` the full
/// buffers are passed straight through (no shuffle, no gather) — the
/// bit-identical pre-pipeline path; otherwise agent rows are shuffled
/// each epoch and gathered into dense row-subset views
/// ([`TrainBatch::gather_rows`]).
#[allow(clippy::too_many_arguments)]
fn learn_on_segment(
    backend: &mut dyn PolicyBackend,
    params: &mut Vec<f32>,
    opt: &mut AdamState,
    cfg: &TrainConfig,
    shuffle_rng: &mut Rng,
    scratch: &mut MinibatchScratch,
    buf: &RolloutBuffer,
    lr: f32,
) -> Result<[f32; 5]> {
    let (adv, ret) = backend.gae(&buf.rewards, &buf.values, &buf.dones, &buf.last_values)?;
    let full = TrainBatch {
        t: buf.horizon,
        r: buf.rows,
        norm_adv: cfg.norm_adv,
        obs: &buf.obs,
        starts: &buf.starts,
        actions: &buf.actions,
        logp: &buf.logp,
        adv: &adv,
        ret: &ret,
    };
    let mut metrics = [0.0f32; 5];
    if cfg.minibatches <= 1 {
        for _ in 0..cfg.epochs {
            metrics = backend.train_step(params, opt, lr, cfg.ent_coef, &full)?;
        }
    } else {
        let mb_rows = buf.rows / cfg.minibatches;
        let mut perm: Vec<usize> = (0..buf.rows).collect();
        for _ in 0..cfg.epochs {
            shuffle_rng.shuffle(&mut perm);
            for rows in perm.chunks_exact(mb_rows) {
                let mb = full.gather_rows(rows, scratch);
                metrics = backend.train_step(params, opt, lr, cfg.ent_coef, &mb)?;
            }
        }
    }
    Ok(metrics)
}

/// Console + CSV metric emission, once per segment.
#[allow(clippy::too_many_arguments)]
fn log_segment(
    cfg: &TrainConfig,
    sink: &mut MetricsSink,
    heartbeat: &mut Option<crate::runs::HeartbeatWriter>,
    global_step: u64,
    window_sps: f64,
    total_steps_done: u64,
    log: &EpisodeLog,
    metrics: &[f32; 5],
    segment: u64,
    tel: &Telemetry,
) -> Result<()> {
    let env_sps = rate(total_steps_done, tel.env_active_s);
    let learn_sps = rate(total_steps_done, tel.learn_s);
    let stall_s = tel.collector_stall_s + tel.learner_stall_s;
    if let Some(hb) = heartbeat.as_mut() {
        hb.beat(global_step, env_sps, learn_sps, stall_s, log.mean_score(100))?;
    }
    if cfg.log_every > 0 && segment % cfg.log_every as u64 == 0 {
        println!(
            "[{}] step {:>8}  sps {:>8.0}  env {:>8.0}  learn {:>8.0}  stall {:>6.2}s  score {:>6}  return {:>8}  loss {:>8.4}  kl {:>7.4}",
            cfg.env,
            global_step,
            window_sps,
            env_sps,
            learn_sps,
            stall_s,
            fmt_opt(log.mean_score(100)),
            fmt_opt(log.mean_return(100)),
            metrics[0],
            metrics[4],
        );
    }
    if let Some(f) = sink.file()? {
        writeln!(
            f,
            "{},{:.0},{},{},{},{},{},{},{},{},{:.0},{:.0},{:.3}",
            global_step,
            window_sps,
            fmt_opt(log.mean_score(100)),
            fmt_opt(log.mean_return(100)),
            fmt_opt(log.mean_length(100)),
            metrics[0],
            metrics[1],
            metrics[2],
            metrics[3],
            metrics[4],
            env_sps,
            learn_sps,
            stall_s,
        )?;
    }
    Ok(())
}

fn fmt_opt(x: Option<f64>) -> String {
    match x {
        Some(v) => format!("{v:.3}"),
        None => "-".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wrappers::WrapperSpec;

    #[test]
    fn trainer_sizes_backend_from_wrapped_spec() {
        let bare = crate::envs::make("ocean/squared", 0);
        let bare_dim = bare.obs_layout().flat_len();
        drop(bare);
        let cfg = TrainConfig {
            env: "ocean/squared".into(),
            wrappers: vec![WrapperSpec::ClipReward(1.0), WrapperSpec::Stack(4)],
            total_steps: 0, // construct only
            log_every: 0,
            ..Default::default()
        };
        let t = Trainer::native(cfg).unwrap();
        assert_eq!(t.policy().spec().obs_dim, 4 * bare_dim);
        // The chain is part of the checkpoint key: a differently-wrapped
        // run can never silently restore these params.
        assert!(t.spec_key.contains("stack=4"), "{}", t.spec_key);
    }

    #[test]
    fn native_trainer_constructs_for_every_ocean_env() {
        for env in crate::envs::OCEAN_ENVS {
            let cfg = TrainConfig {
                env: env.to_string(),
                total_steps: 0, // construct only
                log_every: 0,
                ..Default::default()
            };
            // Every env constructs with its default architecture —
            // recurrent reference specs get the LSTM sandwich and train
            // natively (no more pjrt-only caveat).
            let t = Trainer::native(cfg).unwrap_or_else(|e| panic!("{env}: {e}"));
            assert_eq!(t.policy().params().len(), t.policy().spec().n_params);
            assert_eq!(
                t.policy().spec().lstm,
                crate::backend::native::requires_recurrence(env),
                "{env}: default recurrence"
            );
        }
        // Forcing feedforward on a memory env stays a hard error naming
        // the --policy.lstm fix.
        let err = Trainer::native(TrainConfig {
            env: "ocean/memory".into(),
            policy: Some(PolicySpec::default()),
            total_steps: 0,
            log_every: 0,
            ..Default::default()
        })
        .err()
        .expect("feedforward memory must not construct")
        .to_string();
        assert!(err.contains("--policy.lstm"), "{err}");
    }

    #[test]
    fn explicit_vec_spec_drives_the_vectorizer() {
        // A declarative VecSpec overrides the legacy num_workers/pool
        // knobs entirely.
        let cfg = TrainConfig {
            env: "ocean/bandit".into(),
            num_workers: 4, // ignored: vec wins
            vec: Some(VecSpec::Serial),
            total_steps: 0,
            log_every: 0,
            ..Default::default()
        };
        let t = Trainer::native(cfg).unwrap();
        assert_eq!(t.venv.batch_size(), t.venv.num_envs());
        // A pooled spec halves the recv batch (batch_fwd rows).
        let cfg = TrainConfig {
            env: "ocean/bandit".into(),
            vec: Some(VecSpec::pooled(2)),
            total_steps: 0,
            log_every: 0,
            ..Default::default()
        };
        let t = Trainer::native(cfg).unwrap();
        assert_eq!(t.venv.batch_rows(), t.policy.spec().batch_fwd);
        // A batch size the compiled forward cannot take is a
        // construction error naming vec.batch.
        let cfg = TrainConfig {
            env: "ocean/bandit".into(),
            vec: Some(VecSpec::Mt {
                workers: 8,
                batch: crate::vector::VecBatch::Envs(8),
                zero_copy: false,
                spin_budget: 64,
            }),
            total_steps: 0,
            log_every: 0,
            ..Default::default()
        };
        let err = Trainer::native(cfg).unwrap_err().to_string();
        assert!(err.contains("vec.batch"), "{err}");
    }

    #[test]
    fn minibatches_must_divide_batch_roll() {
        let cfg = TrainConfig {
            env: "ocean/bandit".into(),
            minibatches: 5, // batch_roll is 32
            total_steps: 0,
            log_every: 0,
            ..Default::default()
        };
        let err = Trainer::native(cfg).unwrap_err().to_string();
        assert!(err.contains("minibatches"), "{err}");
    }

    #[test]
    fn anneal_matches_pre_pipeline_formula() {
        let cfg = TrainConfig {
            lr: 1.0,
            anneal_lr: true,
            ..Default::default()
        };
        assert!((anneal_lr(&cfg, 250, 1000) - 0.75).abs() < 1e-6);
        // Floors at 5%.
        assert!((anneal_lr(&cfg, 1000, 1000) - 0.05).abs() < 1e-6);
        let no = TrainConfig {
            lr: 0.3,
            anneal_lr: false,
            ..Default::default()
        };
        assert_eq!(anneal_lr(&no, 900, 1000), 0.3);
    }
}
