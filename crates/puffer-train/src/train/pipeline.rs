//! The experience pipeline behind `train.pipeline.depth > 0`: a
//! **collector** thread owns the [`VecEnv`], runs rollout inference off
//! the latest [`ParamSnapshot`] version, and fills one of `depth + 1`
//! rotating [`RolloutBuffer`] segments while the **learner** (the caller
//! thread, [`Trainer::train`](crate::train::Trainer::train)) consumes the
//! previously completed segment — GAE plus shuffled-minibatch PPO epochs —
//! and publishes fresh parameters for the next acquisition.
//!
//! Buffer rotation doubles as flow control: the collector can run at most
//! `depth` segments ahead because no more buffers exist, and either side
//! exits cleanly when the other hangs up its channel endpoint. Stall time
//! on both sides is measured (the collector's wait for a free buffer, the
//! learner's wait for a filled segment) so `env SPS` vs `learner SPS` and
//! the pipeline balance are observable per run.
//!
//! The transport is [`crate::sync::queue`] rather than `std::sync::mpsc`
//! so the rotation/hangup protocol itself runs under loom — see the
//! `rotation_*` models in `crates/puffer-train/tests/loom_models.rs`.

use super::rollout::{collect_rollout, EpisodeLog, RolloutBuffer};
use crate::backend::PolicyBackend;
use crate::policy::{ParamSnapshot, Policy};
use crate::sync::queue;
use crate::util::timer::Timer;
use crate::vector::VecEnv;
use anyhow::Result;

/// One collected rollout segment in flight from collector to learner.
pub struct Segment {
    pub buf: RolloutBuffer,
    /// Episode stats harvested while collecting this segment.
    pub log: EpisodeLog,
    /// Param snapshot version the collector inferred with.
    pub version: u64,
    /// Env steps stored in the segment (`horizon × batch_roll`).
    pub steps: u64,
    /// Wall-clock seconds spent collecting (inference + env stepping).
    pub collect_s: f64,
    /// Seconds the collector stalled waiting for a free buffer before
    /// this segment — the learner-is-too-slow signal.
    pub stall_s: f64,
}

/// Collector half of the pipeline; runs on a dedicated scoped thread.
///
/// Resets the venv, then for each of `segments_total` segments: waits for
/// a free buffer, acquires the newest published params into `policy`,
/// threads the episode-boundary carry from the previous segment in, and
/// collects. Recurrent policy state (`h`/`c`) lives in `policy` and is
/// carried across segments exactly as the serial loop carries it across
/// iterations. Exits early (without panicking) when the learner hangs up.
#[allow(clippy::too_many_arguments)]
pub(crate) fn collector_loop(
    venv: &mut dyn VecEnv,
    policy: &mut Policy,
    backend: &mut dyn PolicyBackend,
    snapshot: &ParamSnapshot,
    free_rx: queue::Receiver<RolloutBuffer>,
    filled_tx: queue::Sender<Result<Segment>>,
    segments_total: u64,
    seed: u64,
) {
    venv.async_reset(seed);
    policy.reset_all_state();
    let rows = policy.spec().batch_roll;
    let mut carry = vec![true; rows]; // hard reset: every row starts fresh

    for _ in 0..segments_total {
        let wait = Timer::start();
        let Some(mut buf) = free_rx.recv() else {
            return; // learner dropped its sender (done or errored)
        };
        let stall_s = wait.secs();

        let (version, params) = snapshot.acquire();
        policy.set_params(&params);
        buf.set_episode_carry(&carry);

        let mut log = EpisodeLog::default();
        let collect = Timer::start();
        let res = collect_rollout(venv, &mut buf, &mut log, |obs, rows, done_rows| {
            // Zero recurrent state for rows whose episode just ended
            // *before* the forward pass on their fresh observations —
            // the LSTM state-reset discipline of paper §3.4.
            for &r in done_rows {
                policy.reset_state(r);
            }
            policy.step(&mut *backend, obs, rows)
        });
        let collect_s = collect.secs();
        carry.copy_from_slice(buf.episode_carry());

        let msg = match res {
            Ok(()) => {
                let steps = buf.segment_steps() as u64;
                Ok(Segment {
                    buf,
                    log,
                    version,
                    steps,
                    collect_s,
                    stall_s,
                })
            }
            Err(e) => Err(e),
        };
        let failed = msg.is_err();
        if filled_tx.send(msg).is_err() || failed {
            return;
        }
    }
}
