//! Binary checkpoints: flat params + Adam state + counters, plus (since
//! v2) the serialized [`RunSpec`](crate::runspec::RunSpec) of the run
//! that wrote them. Format: magic, version, spec-key, run-spec JSON,
//! then length-prefixed f32 arrays, all little-endian — no serde
//! needed, stable across runs. v1 files (pre-RunSpec) still load, with
//! no embedded spec.

use anyhow::{Context, Result};
use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 8] = b"PUFFCKPT";
const VERSION: u32 = 2;

/// Everything needed to resume training.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub spec_key: String,
    /// The compact-JSON [`RunSpec`](crate::runspec::RunSpec) of the run
    /// that wrote this checkpoint, when it was constructed through
    /// `Trainer::from_run_spec` — what lets `puffer resume <ckpt>` /
    /// `puffer eval <ckpt>` rebuild the whole experiment with zero
    /// flags. `None` for v1 files and directly-configured trainers.
    pub run_spec_json: Option<String>,
    pub global_step: u64,
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub adam_step: f32,
}

impl Checkpoint {
    /// Serialize and publish atomically (tmp sibling + fsync + rename,
    /// via [`crate::runs::fsio::write_atomic`]): the checkpoint is the
    /// only resumable artifact, so a kill mid-save must leave either
    /// the previous complete file or the new one — never a torn hybrid.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let mut bytes = Vec::with_capacity(
            64 + self.spec_key.len()
                + self.run_spec_json.as_deref().unwrap_or("").len()
                + 4 * (self.params.len() + self.adam_m.len() + self.adam_v.len()),
        );
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        let key = self.spec_key.as_bytes();
        bytes.extend_from_slice(&(key.len() as u32).to_le_bytes());
        bytes.extend_from_slice(key);
        // Length-prefixed run spec; 0 = none.
        let spec = self.run_spec_json.as_deref().unwrap_or("").as_bytes();
        bytes.extend_from_slice(&(spec.len() as u32).to_le_bytes());
        bytes.extend_from_slice(spec);
        bytes.extend_from_slice(&self.global_step.to_le_bytes());
        bytes.extend_from_slice(&self.adam_step.to_le_bytes());
        for arr in [&self.params, &self.adam_m, &self.adam_v] {
            bytes.extend_from_slice(&(arr.len() as u64).to_le_bytes());
            for x in arr.iter() {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
        }
        crate::runs::fsio::write_atomic(path, &bytes)
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Read just the magic and format version — what `puffer ckpt info`
    /// and serve use to tell a v1 (spec-less) file apart from a corrupt
    /// one without pulling three parameter arrays into memory.
    pub fn probe_version(path: impl AsRef<Path>) -> Result<u32> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a puffer checkpoint");
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        Ok(u32::from_le_bytes(u32b))
    }

    /// Read the header only — format version plus the resume step —
    /// skipping over the embedded strings and never touching the three
    /// parameter arrays. Resumable sweeps use this to classify a child
    /// as at-budget vs partial without paying a full `load` per grid
    /// point.
    pub fn probe_progress(path: impl AsRef<Path>) -> Result<(u32, u64)> {
        use std::io::{Seek, SeekFrom};
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a puffer checkpoint");
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        let version = u32::from_le_bytes(u32b);
        anyhow::ensure!(
            version == 1 || version == VERSION,
            "checkpoint version {version} not supported (this build reads v1 and v{VERSION})"
        );
        // Skip the length-prefixed spec-key (and run-spec JSON, v2+).
        let strings = if version >= 2 { 2 } else { 1 };
        for _ in 0..strings {
            f.read_exact(&mut u32b)?;
            f.seek(SeekFrom::Current(u32::from_le_bytes(u32b) as i64))?;
        }
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        Ok((version, u64::from_le_bytes(u64b)))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a puffer checkpoint");
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        let version = u32::from_le_bytes(u32b);
        anyhow::ensure!(
            version == 1 || version == VERSION,
            "checkpoint version {version} not supported (this build reads v1 and v{VERSION})"
        );
        let read_string = |f: &mut std::fs::File| -> Result<String> {
            let mut lenb = [0u8; 4];
            f.read_exact(&mut lenb)?;
            let len = u32::from_le_bytes(lenb) as usize;
            let mut bytes = vec![0u8; len];
            f.read_exact(&mut bytes)?;
            String::from_utf8(bytes).context("bad checkpoint string")
        };
        let spec_key = read_string(&mut f)?;
        let run_spec_json = if version >= 2 {
            let s = read_string(&mut f)?;
            if s.is_empty() {
                None
            } else {
                Some(s)
            }
        } else {
            None
        };
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        let global_step = u64::from_le_bytes(u64b);
        f.read_exact(&mut u32b)?;
        let adam_step = f32::from_le_bytes(u32b);
        let read_arr = |f: &mut std::fs::File| -> Result<Vec<f32>> {
            let mut lenb = [0u8; 8];
            f.read_exact(&mut lenb)?;
            let len = u64::from_le_bytes(lenb) as usize;
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                // PANIC: chunks_exact(4) yields exactly 4-byte chunks.
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        };
        let params = read_arr(&mut f)?;
        let adam_m = read_arr(&mut f)?;
        let adam_v = read_arr(&mut f)?;
        Ok(Checkpoint {
            spec_key,
            run_spec_json,
            global_step,
            params,
            adam_m,
            adam_v,
            adam_step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(run_spec_json: Option<String>) -> Checkpoint {
        Checkpoint {
            spec_key: "ocean_squared".into(),
            run_spec_json,
            global_step: 12_345,
            params: vec![1.5, -2.0, 0.25],
            adam_m: vec![0.1, 0.2, 0.3],
            adam_v: vec![0.0; 3],
            adam_step: 7.0,
        }
    }

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("puffer_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        for (name, ck) in [
            ("plain.bin", sample(None)),
            (
                "spec.bin",
                sample(Some(r#"{"env":{"name":"ocean/squared"}}"#.into())),
            ),
        ] {
            let path = dir.join(name);
            ck.save(&path).unwrap();
            let back = Checkpoint::load(&path).unwrap();
            assert_eq!(ck, back);
        }
    }

    #[test]
    fn v1_files_still_load_without_a_run_spec() {
        // Hand-write the v1 layout: magic, version 1, spec-key,
        // global_step, adam_step, three arrays.
        let dir = std::env::temp_dir().join("puffer_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.bin");
        let ck = sample(None);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&(ck.spec_key.len() as u32).to_le_bytes());
        bytes.extend_from_slice(ck.spec_key.as_bytes());
        bytes.extend_from_slice(&ck.global_step.to_le_bytes());
        bytes.extend_from_slice(&ck.adam_step.to_le_bytes());
        for arr in [&ck.params, &ck.adam_m, &ck.adam_v] {
            bytes.extend_from_slice(&(arr.len() as u64).to_le_bytes());
            for x in arr.iter() {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
        }
        std::fs::write(&path, bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.run_spec_json, None);
        assert_eq!(Checkpoint::probe_version(&path).unwrap(), 1);
    }

    #[test]
    fn probe_version_reads_the_header_only() {
        let dir = std::env::temp_dir().join("puffer_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("probe.bin");
        sample(None).save(&path).unwrap();
        assert_eq!(Checkpoint::probe_version(&path).unwrap(), VERSION);
        // A bare header probes fine even though load() would fail.
        let path = dir.join("header_only.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&7u32.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(Checkpoint::probe_version(&path).unwrap(), 7);
        assert!(Checkpoint::probe_version(dir.join("garbage.bin")).is_err());
    }

    #[test]
    fn save_is_atomic_and_truncated_files_are_rejected() {
        let dir = std::env::temp_dir().join("puffer_ckpt_atomic_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("checkpoint.bin");
        let ck = sample(Some(r#"{"env":{"name":"ocean/squared"}}"#.into()));
        ck.save(&path).unwrap();
        // The tmp sibling must be renamed away, and re-saving must
        // replace in place (the overwrite path a trainer hits every
        // checkpoint interval).
        assert!(!dir.join("checkpoint.bin.tmp").exists());
        let mut ck2 = ck.clone();
        ck2.global_step = 99_999;
        ck2.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck2);
        assert_eq!(Checkpoint::probe_progress(&path).unwrap(), (VERSION, 99_999));

        // Every strict prefix of the file must be rejected by load():
        // a torn write can never masquerade as a resumable checkpoint.
        let full = std::fs::read(&path).unwrap();
        let cut_points = [
            4,              // inside the magic
            10,             // inside the version
            14,             // inside the spec-key
            full.len() / 2, // mid-arrays
            full.len() - 1, // one byte short
        ];
        for cut in cut_points {
            let torn = dir.join(format!("torn_{cut}.bin"));
            std::fs::write(&torn, &full[..cut]).unwrap();
            assert!(
                Checkpoint::load(&torn).is_err(),
                "a {cut}-byte prefix of a {}-byte checkpoint must not load",
                full.len()
            );
        }
        // probe_progress reads only the header, so it accepts any
        // prefix that still contains one — but never a torn header.
        assert!(Checkpoint::probe_progress(dir.join("torn_4.bin")).is_err());
        assert!(Checkpoint::probe_progress(dir.join("torn_14.bin")).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("puffer_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        // An unknown future version is rejected with the version named.
        let path = dir.join("future.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("99"), "{err}");
    }
}
