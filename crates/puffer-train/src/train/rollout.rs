//! Rollout collection over any [`VecEnv`] — including pooled (EnvPool)
//! backends where each `recv` returns a different subset of env rows.
//!
//! Bookkeeping is per *global row* (env × agent): each row keeps its own
//! time cursor, so fast envs from early batches and stragglers from late
//! ones all assemble into one dense time-major `(T, R)` rollout. A row's
//! reward/done arrives one `recv` after its (obs, action) was stored; the
//! first value seen after a row fills `T` slots becomes its GAE bootstrap.

use crate::emulation::Info;
use crate::policy::PolicyOut;
use crate::vector::VecEnv;
use anyhow::Result;

/// Time-major rollout storage, width `rows` = total agent rows (`R`),
/// depth `horizon` = `T`.
pub struct RolloutBuffer {
    pub horizon: usize,
    pub rows: usize,
    pub obs_dim: usize,
    pub slots: usize,

    /// `(T, R, D)` f32, time-major.
    pub obs: Vec<f32>,
    /// `(T, R)`: 1.0 where the stored obs begins a new episode (LSTM
    /// state reset marker).
    pub starts: Vec<f32>,
    /// `(T, R, S)` i32.
    pub actions: Vec<i32>,
    /// `(T, R)`.
    pub logp: Vec<f32>,
    pub values: Vec<f32>,
    pub rewards: Vec<f32>,
    pub dones: Vec<f32>,
    /// `(R,)` bootstrap values.
    pub last_values: Vec<f32>,

    cursor: Vec<usize>,
    pending: Vec<bool>,
    complete: Vec<bool>,
    /// Persisted across rollouts: the next obs stored for this row starts
    /// a new episode.
    next_start: Vec<bool>,
}

impl RolloutBuffer {
    pub fn new(horizon: usize, rows: usize, obs_dim: usize, slots: usize) -> Self {
        RolloutBuffer {
            horizon,
            rows,
            obs_dim,
            slots,
            obs: vec![0.0; horizon * rows * obs_dim],
            starts: vec![0.0; horizon * rows],
            actions: vec![0; horizon * rows * slots],
            logp: vec![0.0; horizon * rows],
            values: vec![0.0; horizon * rows],
            rewards: vec![0.0; horizon * rows],
            dones: vec![0.0; horizon * rows],
            last_values: vec![0.0; rows],
            cursor: vec![0; rows],
            pending: vec![false; rows],
            complete: vec![false; rows],
            next_start: vec![true; rows],
        }
    }

    /// Prepare for a fresh segment (cursors reset; `next_start` persists
    /// so episodes spanning segments keep correct LSTM reset flags).
    pub fn begin_segment(&mut self) {
        self.cursor.fill(0);
        self.pending.fill(false);
        self.complete.fill(false);
    }

    /// Mark every row as starting a new episode (after a hard env reset).
    pub fn mark_all_starts(&mut self) {
        self.next_start.fill(true);
    }

    /// Episode-boundary carry: for each row, whether its *next* stored obs
    /// begins a new episode. The serial loop reuses one buffer so this
    /// state persists implicitly; the pipelined trainer rotates several
    /// buffers and must thread it from the segment just collected into
    /// the buffer about to be filled ([`Self::set_episode_carry`]).
    pub fn episode_carry(&self) -> &[bool] {
        &self.next_start
    }

    /// Restore the episode-boundary carry exported from the previous
    /// segment's buffer (see [`Self::episode_carry`]).
    pub fn set_episode_carry(&mut self, carry: &[bool]) {
        assert_eq!(carry.len(), self.rows, "carry length != buffer rows");
        self.next_start.copy_from_slice(carry);
    }

    pub fn all_complete(&self) -> bool {
        self.complete.iter().all(|&c| c)
    }

    /// Total transitions stored in the segment.
    pub fn segment_steps(&self) -> usize {
        self.horizon * self.rows
    }

    #[inline]
    fn idx(&self, t: usize, row: usize) -> usize {
        t * self.rows + row
    }

    /// Attribute an arriving (reward, done) to the row's pending
    /// transition. Returns true if the row's episode ended (the caller
    /// should zero any recurrent state).
    pub fn attribute(&mut self, row: usize, reward: f32, done: bool) -> bool {
        if !self.pending[row] {
            return false; // first recv after reset: nothing outstanding
        }
        let t = self.cursor[row] - 1;
        let i = self.idx(t, row);
        self.rewards[i] = reward;
        self.dones[i] = if done { 1.0 } else { 0.0 };
        self.pending[row] = false;
        if done {
            self.next_start[row] = true;
        }
        done
    }

    /// Store a new decision point for the row, or capture its bootstrap
    /// value if the segment is already full. Returns `true` if stored
    /// (the row still collects).
    pub fn store(
        &mut self,
        row: usize,
        obs_row: &[f32],
        action_row: &[i32],
        logp: f32,
        value: f32,
    ) -> bool {
        debug_assert_eq!(obs_row.len(), self.obs_dim);
        debug_assert_eq!(action_row.len(), self.slots);
        let t = self.cursor[row];
        if t >= self.horizon {
            if !self.complete[row] {
                self.last_values[row] = value;
                self.complete[row] = true;
            }
            return false;
        }
        let i = self.idx(t, row);
        self.obs[i * self.obs_dim..(i + 1) * self.obs_dim].copy_from_slice(obs_row);
        self.actions[i * self.slots..(i + 1) * self.slots].copy_from_slice(action_row);
        self.logp[i] = logp;
        self.values[i] = value;
        self.starts[i] = if self.next_start[row] { 1.0 } else { 0.0 };
        self.next_start[row] = false;
        self.pending[row] = true;
        self.cursor[row] = t + 1;
        true
    }
}

/// Episode statistics harvested from env infos during collection.
#[derive(Clone, Debug, Default)]
pub struct EpisodeLog {
    pub returns: Vec<f64>,
    pub lengths: Vec<f64>,
    pub scores: Vec<f64>,
}

impl EpisodeLog {
    pub fn absorb(&mut self, infos: &[(usize, Info)]) {
        for (_, info) in infos {
            for (k, v) in info {
                match *k {
                    "episode_return" => self.returns.push(*v),
                    "episode_length" => self.lengths.push(*v),
                    "score" => self.scores.push(*v),
                    _ => {}
                }
            }
        }
    }

    /// Append another log's episodes (the pipelined trainer collects into
    /// a per-segment log on the collector thread and merges learner-side,
    /// preserving arrival order for the windowed means).
    pub fn merge(&mut self, other: &EpisodeLog) {
        self.returns.extend_from_slice(&other.returns);
        self.lengths.extend_from_slice(&other.lengths);
        self.scores.extend_from_slice(&other.scores);
    }

    pub fn mean_score(&self, window: usize) -> Option<f64> {
        mean_tail(&self.scores, window)
    }
    pub fn mean_return(&self, window: usize) -> Option<f64> {
        mean_tail(&self.returns, window)
    }
    pub fn mean_length(&self, window: usize) -> Option<f64> {
        mean_tail(&self.lengths, window)
    }
}

fn mean_tail(xs: &[f64], window: usize) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let tail = &xs[xs.len().saturating_sub(window)..];
    Some(tail.iter().sum::<f64>() / tail.len() as f64)
}

/// Collect one full `(T, R)` segment from `venv`, calling `policy_step`
/// for each received batch. `policy_step(obs_f32, global_rows, done_rows)`
/// returns the sampled actions/logps/values for those rows; `done_rows`
/// lists the global rows whose episode just ended — the policy must zero
/// any recurrent state for them *before* the forward pass (their obs
/// begins a fresh episode thanks to auto-reset).
///
/// Works on every backend mode: sync needs exactly `T + 1` recvs; pooled
/// modes take as many as the stragglers require, with surplus frames from
/// fast envs simply driven (actions computed and sent) but not stored.
pub fn collect_rollout<F>(
    venv: &mut dyn VecEnv,
    buf: &mut RolloutBuffer,
    log: &mut EpisodeLog,
    mut policy_step: F,
) -> Result<()>
where
    F: FnMut(&[f32], &[usize], &[usize]) -> Result<PolicyOut>,
{
    let agents = venv.agents_per_env();
    let layout = venv.obs_layout().clone();
    let d = layout.flat_len();
    let slots = venv.action_dims().len();
    buf.begin_segment();

    let mut obs_f32: Vec<f32> = Vec::new();
    let mut global_rows: Vec<usize> = Vec::new();
    let mut done_rows: Vec<usize> = Vec::new();
    let mut actions_out: Vec<i32> = Vec::new();

    while !buf.all_complete() {
        // recv: obs o_t for a batch of rows; rewards/dones for those rows'
        // *previous* actions.
        let (rewards, terms, truncs, raw_obs, env_ids, infos) = {
            let b = venv.recv()?;
            (
                b.rewards.to_vec(),
                b.terms.to_vec(),
                b.truncs.to_vec(),
                b.obs.to_vec(),
                b.env_ids.to_vec(),
                b.infos,
            )
        };
        log.absorb(&infos);

        global_rows.clear();
        for &e in &env_ids {
            for a in 0..agents {
                global_rows.push(e * agents + a);
            }
        }
        let rows = global_rows.len();

        // 1) Attribute last step's rewards.
        done_rows.clear();
        for (i, &g) in global_rows.iter().enumerate() {
            let done = terms[i] || truncs[i];
            if buf.attribute(g, rewards[i], done) {
                done_rows.push(g);
            }
        }

        // 2) Policy forward on the fresh observations (recurrent state of
        //    done_rows zeroed inside the closure first).
        obs_f32.resize(rows * d, 0.0);
        for (i, row) in raw_obs.chunks_exact(layout.byte_len()).enumerate() {
            layout.row_to_f32(row, &mut obs_f32[i * d..(i + 1) * d]);
        }
        let out = policy_step(&obs_f32, &global_rows, &done_rows)?;

        // 3) Store decision points (or bootstrap values for full rows).
        for (i, &g) in global_rows.iter().enumerate() {
            buf.store(
                g,
                &obs_f32[i * d..(i + 1) * d],
                &out.actions[i * slots..(i + 1) * slots],
                out.logp[i],
                out.values[i],
            );
        }

        // 4) Send the actions back regardless — envs must keep running.
        actions_out.clear();
        actions_out.extend_from_slice(&out.actions[..rows * slots]);
        venv.send(&actions_out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicyOut;
    use crate::vector::{Multiprocessing, Serial, VecConfig};

    fn fake_policy(obs: &[f32], rows: &[usize], d: usize, slots: usize) -> PolicyOut {
        // Deterministic: value = first obs elem; action = row id % 2.
        let n = rows.len();
        PolicyOut {
            actions: rows
                .iter()
                .flat_map(|&g| std::iter::repeat((g % 2) as i32).take(slots))
                .collect(),
            logp: vec![-0.7; n],
            values: (0..n).map(|i| obs[i * d]).collect(),
        }
    }

    #[test]
    fn sync_collection_fills_exactly() {
        let cfg = VecConfig {
            num_envs: 4,
            num_workers: 1,
            batch_size: 4,
            ..Default::default()
        };
        let mut v =
            Serial::from_spec(&crate::wrappers::EnvSpec::new("classic/cartpole"), cfg).unwrap();
        let d = v.obs_layout().flat_len();
        let slots = v.action_dims().len();
        let mut buf = RolloutBuffer::new(8, 4, d, slots);
        let mut log = EpisodeLog::default();
        v.async_reset(0);
        collect_rollout(
            &mut v,
            &mut buf,
            &mut log,
            |obs, rows, _done| Ok(fake_policy(obs, rows, d, slots)),
        )
        .unwrap();
        assert!(buf.all_complete());
        // Every slot stored: starts[0, :] all 1 (fresh reset).
        assert!(buf.starts[..4].iter().all(|&s| s == 1.0));
        // Later starts only where an episode ended.
        let interior_starts: f32 = buf.starts[4..].iter().sum();
        let dones: f32 = buf.dones.iter().sum();
        assert!(interior_starts <= dones + 1e-6);
    }

    #[test]
    fn pooled_collection_completes_with_stragglers() {
        use crate::emulation::PufferEnv;
        use crate::envs::profile::{ProfileConfig, ProfileSim};
        let factory = |i: usize| -> Box<dyn crate::emulation::FlatEnv> {
            // Worker 1's envs are 20x slower.
            let step_us = if i >= 2 { 400.0 } else { 20.0 };
            Box::new(PufferEnv::new(ProfileSim::new(
                ProfileConfig::synthetic(step_us, 0.3, 0.0, 4),
                i as u64,
            )))
        };
        let cfg = VecConfig {
            num_envs: 4,
            num_workers: 2,
            batch_size: 2,
            ..Default::default()
        };
        let mut v = Multiprocessing::from_factory(factory, cfg).unwrap();
        let d = v.obs_layout().flat_len();
        let slots = v.action_dims().len();
        let mut buf = RolloutBuffer::new(6, 4, d, slots);
        let mut log = EpisodeLog::default();
        v.async_reset(0);
        collect_rollout(
            &mut v,
            &mut buf,
            &mut log,
            |obs, rows, _done| Ok(fake_policy(obs, rows, d, slots)),
        )
        .unwrap();
        assert!(buf.all_complete());
        // All rows filled all T slots despite imbalance: values recorded
        // everywhere (value = obs[0], cartpole obs nonzero generally; just
        // check cursor bookkeeping via dones/rewards shape).
        assert_eq!(buf.rewards.len(), 6 * 4);
    }

    #[test]
    fn attribute_before_store_is_noop() {
        let mut buf = RolloutBuffer::new(4, 2, 3, 1);
        buf.begin_segment();
        assert!(!buf.attribute(0, 1.0, true), "nothing pending yet");
        assert_eq!(buf.rewards[0], 0.0);
    }

    #[test]
    fn bootstrap_captured_after_full() {
        let mut buf = RolloutBuffer::new(2, 1, 1, 1);
        buf.begin_segment();
        assert!(buf.store(0, &[0.1], &[0], -0.5, 10.0));
        buf.attribute(0, 1.0, false);
        assert!(buf.store(0, &[0.2], &[1], -0.5, 11.0));
        buf.attribute(0, 2.0, false);
        // Row full: next store captures the bootstrap instead.
        assert!(!buf.store(0, &[0.3], &[0], -0.5, 99.0));
        assert!(buf.all_complete());
        assert_eq!(buf.last_values[0], 99.0);
        assert_eq!(buf.rewards, vec![1.0, 2.0]);
        assert_eq!(buf.values, vec![10.0, 11.0]);
    }

    #[test]
    fn episode_carry_transfers_across_buffers() {
        // An episode ends at the tail of buffer A; the carry moved into
        // buffer B must flag B's first stored obs as an episode start.
        let mut a = RolloutBuffer::new(1, 2, 1, 1);
        a.mark_all_starts();
        a.begin_segment();
        a.store(0, &[0.0], &[0], -0.5, 0.0);
        a.store(1, &[0.0], &[0], -0.5, 0.0);
        a.attribute(0, 1.0, true); // row 0's episode ends
        a.attribute(1, 0.0, false);
        assert_eq!(a.episode_carry(), &[true, false]);

        let mut b = RolloutBuffer::new(1, 2, 1, 1);
        b.next_start.fill(false); // stale state from a previous rotation
        b.set_episode_carry(a.episode_carry());
        b.begin_segment();
        b.store(0, &[0.0], &[0], -0.5, 0.0);
        b.store(1, &[0.0], &[0], -0.5, 0.0);
        assert_eq!(b.starts, vec![1.0, 0.0]);
    }

    #[test]
    fn start_flags_track_episode_boundaries() {
        let mut buf = RolloutBuffer::new(3, 1, 1, 1);
        buf.mark_all_starts();
        buf.begin_segment();
        buf.store(0, &[0.0], &[0], -0.5, 0.0);
        buf.attribute(0, 1.0, true); // episode ends
        buf.store(0, &[0.0], &[0], -0.5, 0.0);
        buf.attribute(0, 1.0, false);
        buf.store(0, &[0.0], &[0], -0.5, 0.0);
        assert_eq!(buf.starts, vec![1.0, 1.0, 0.0]);
        assert_eq!(buf.dones, vec![1.0, 0.0, 0.0]);
    }
}
