//! [`PjrtBackend`] (`pjrt` cargo feature) — the AOT execution path: JAX/
//! Pallas entry points lowered to HLO text by `python/compile/aot.py` and
//! executed through the PJRT C API via the [`Runtime`]. This is the
//! original three-layer stack; the backend trait wraps it so the trainer
//! and policy no longer know about literals or artifacts.

use super::{AdamState, Forward, ForwardLstm, PolicyBackend, TrainBatch};
use crate::runtime::{
    lit_f32, lit_f32_2d, lit_f32_3d, lit_i32_2d, lit_i32_3d, lit_scalar, to_f32s, Runtime,
    SpecManifest,
};
use anyhow::{Context, Result};

/// PJRT-backed compute: compiles the manifest's HLO artifacts lazily and
/// runs them on the CPU PJRT client.
pub struct PjrtBackend {
    rt: Runtime,
    key: String,
    spec: SpecManifest,
    artifacts_dir: String,
}

impl PjrtBackend {
    /// Load the manifest from `artifacts_dir` and bind to `spec_key`
    /// (e.g. `"ocean_bandit"`).
    pub fn new(artifacts_dir: &str, spec_key: &str) -> Result<Self> {
        let rt = Runtime::new(artifacts_dir)?;
        let spec = rt.manifest().spec(spec_key)?.clone();
        Ok(PjrtBackend {
            rt,
            key: spec_key.to_string(),
            spec,
            artifacts_dir: artifacts_dir.to_string(),
        })
    }

    /// The underlying runtime (extra entry points, contract checks).
    pub fn runtime(&mut self) -> &mut Runtime {
        &mut self.rt
    }
}

impl PolicyBackend for PjrtBackend {
    fn spec(&self) -> &SpecManifest {
        &self.spec
    }

    fn key(&self) -> &str {
        &self.key
    }

    fn init_params(&mut self) -> Result<Vec<f32>> {
        // aot.py exports the ravel_pytree-ordered initial vector; reading
        // it back avoids re-deriving the pytree layout in Rust.
        let path = format!("{}/{}", self.artifacts_dir, self.spec.params0);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path}"))?;
        anyhow::ensure!(
            bytes.len() == 4 * self.spec.n_params,
            "params0 size {} != 4 * n_params {}",
            bytes.len(),
            self.spec.n_params
        );
        Ok(bytes
            .chunks_exact(4)
            // PANIC: chunks_exact(4) yields exactly 4-byte chunks.
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn forward(&mut self, params: &[f32], obs: &[f32], rows: usize) -> Result<Forward> {
        let d = self.spec.obs_dim;
        anyhow::ensure!(obs.len() == rows * d, "obs len {} != {rows}x{d}", obs.len());
        let exe = self.rt.load(&self.key, &format!("forward_b{rows}"))?;
        let out = exe.run(&[lit_f32(params), lit_f32_2d(obs, rows, d)?])?;
        anyhow::ensure!(out.len() == 2, "forward returns (logits, value)");
        Ok(Forward {
            logits: to_f32s(&out[0])?,
            values: to_f32s(&out[1])?,
        })
    }

    fn forward_lstm(
        &mut self,
        params: &[f32],
        obs: &[f32],
        h: &[f32],
        c: &[f32],
        rows: usize,
    ) -> Result<ForwardLstm> {
        let d = self.spec.obs_dim;
        let hdim = self.spec.hidden;
        anyhow::ensure!(obs.len() == rows * d, "obs len {} != {rows}x{d}", obs.len());
        let exe = self.rt.load(&self.key, &format!("forward_lstm_b{rows}"))?;
        let out = exe.run(&[
            lit_f32(params),
            lit_f32_2d(obs, rows, d)?,
            lit_f32_2d(h, rows, hdim)?,
            lit_f32_2d(c, rows, hdim)?,
        ])?;
        anyhow::ensure!(out.len() == 4, "forward_lstm returns 4 outputs");
        Ok(ForwardLstm {
            logits: to_f32s(&out[0])?,
            values: to_f32s(&out[1])?,
            h: to_f32s(&out[2])?,
            c: to_f32s(&out[3])?,
        })
    }

    fn gae(
        &mut self,
        rewards: &[f32],
        values: &[f32],
        dones: &[f32],
        last_values: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let (t, r) = (self.spec.horizon, self.spec.batch_roll);
        let exe = self.rt.load(&self.key, "gae")?;
        let outs = exe.run(&[
            lit_f32_2d(rewards, t, r)?,
            lit_f32_2d(values, t, r)?,
            lit_f32_2d(dones, t, r)?,
            lit_f32(last_values),
        ])?;
        anyhow::ensure!(outs.len() == 2, "gae returns (adv, ret)");
        Ok((to_f32s(&outs[0])?, to_f32s(&outs[1])?))
    }

    fn train_step(
        &mut self,
        params: &mut Vec<f32>,
        opt: &mut AdamState,
        lr: f32,
        ent_coef: f32,
        batch: &TrainBatch<'_>,
    ) -> Result<[f32; 5]> {
        let spec = &self.spec;
        // The AOT-lowered train_step bakes batch advantage normalization
        // into the compiled graph; it cannot be toggled per call.
        anyhow::ensure!(
            batch.norm_adv,
            "the pjrt backend's compiled train_step always normalizes \
             advantages; train.norm_adv=false requires the native backend"
        );
        // Fixed-shape executable: a minibatch view (r < batch_roll) can
        // never match the lowered argument shapes — fail with the config
        // fix instead of an opaque XLA shape error.
        anyhow::ensure!(
            batch.t == spec.horizon && batch.r == spec.batch_roll,
            "the pjrt train_step was AOT-lowered for (T={}, R={}), got \
             (T={}, R={}); train.minibatches > 1 requires the native backend",
            spec.horizon,
            spec.batch_roll,
            batch.t,
            batch.r
        );
        let (t, r) = (batch.t, batch.r);
        let n = t * r;
        let slots = spec.act_dims.len();
        let inputs: Vec<xla::Literal> = if spec.lstm {
            vec![
                lit_f32(params),
                lit_f32(&opt.m),
                lit_f32(&opt.v),
                lit_scalar(opt.step),
                lit_scalar(lr),
                lit_scalar(ent_coef),
                lit_f32_3d(batch.obs, t, r, spec.obs_dim)?,
                lit_f32_2d(batch.starts, t, r)?,
                lit_i32_3d(batch.actions, t, r, slots)?,
                lit_f32_2d(batch.logp, t, r)?,
                lit_f32_2d(batch.adv, t, r)?,
                lit_f32_2d(batch.ret, t, r)?,
            ]
        } else {
            vec![
                lit_f32(params),
                lit_f32(&opt.m),
                lit_f32(&opt.v),
                lit_scalar(opt.step),
                lit_scalar(lr),
                lit_scalar(ent_coef),
                lit_f32_2d(batch.obs, n, spec.obs_dim)?,
                lit_i32_2d(batch.actions, n, slots)?,
                lit_f32(batch.logp),
                lit_f32(batch.adv),
                lit_f32(batch.ret),
            ]
        };
        let exe = self.rt.load(&self.key, "train_step")?;
        let outs = exe.run(&inputs)?;
        anyhow::ensure!(outs.len() == 5, "train_step returns 5 outputs");
        *params = to_f32s(&outs[0])?;
        opt.m = to_f32s(&outs[1])?;
        opt.v = to_f32s(&outs[2])?;
        opt.step = to_f32s(&outs[3])?[0];
        let m = to_f32s(&outs[4])?;
        anyhow::ensure!(m.len() == 5, "metrics must be length 5");
        let mut metrics = [0.0f32; 5];
        metrics.copy_from_slice(&m);
        Ok(metrics)
    }
}
