//! [`NativeBackend`] — the default, dependency-free compute backend: a
//! pure-Rust port of the reference math the Pallas kernels are checked
//! against (`python/compile/kernels/ref.py`, `gae.py`) and of the Clean
//! PuffeRL learner in `python/compile/model.py`.
//!
//! Since the PolicySpec redesign the backend builds its forward **and
//! backward** passes from a [`ResolvedPolicy`] — the declarative
//! [`PolicySpec`] bound to the env's emulated observation layout:
//!
//! - per-leaf observation encoders (raw f32 pass-through, or learned
//!   embedding tables for Discrete/token leaves) concatenated into the
//!   two-layer tanh trunk (the fused `linear_act` kernel's
//!   `y = act(x @ w + b)` contract),
//! - recurrence as a composable stage: the fused-gate LSTM cell on the
//!   rollout side **and full BPTT through the time scan on the training
//!   side** (`model.py::train_step_lstm`), over whole rollout rows with
//!   episode-start state masking — recurrent envs train natively,
//! - the GAE reverse time scan,
//! - the full clipped-surrogate PPO update: hand-derived backprop through
//!   every stage, global-norm gradient clipping, and Adam — bit-for-bit
//!   the same update rule as `model._adam`.
//!
//! The flat parameter vector uses the same layout as the PJRT path:
//! JAX's `ravel_pytree` flattens the params dict in alphabetical leaf
//! order (`actor.b, actor.w, critic.b, critic.w[, embed_00.w …], enc1.b,
//! enc1.w, enc2.b, enc2.w[, lstm.b, lstm.w]`), so checkpoints are
//! interchangeable across backends for matching architectures. The
//! default [`PolicySpec`] reproduces the pre-PolicySpec model bit for
//! bit; parity with the JAX reference (including embedding fwd/bwd and
//! LSTM BPTT gradients) is pinned by `crates/puffer-train/tests/native_parity.rs`
//! against checked-in fixtures.

use super::kernels::elementwise::{FastMath, ScalarMath, StdMath};
use super::kernels::{self, gemm, KernelPath};
use super::{AdamState, Forward, ForwardLstm, PolicyBackend, TrainBatch};
use crate::emulation::FlatEnv;
use crate::policy::arch::{ArchRanges, PolicySpec, ResolvedPolicy, TrunkSegment};
use crate::runtime::{Manifest, SpecManifest};
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::borrow::Cow;
use std::collections::BTreeMap;

pub use crate::policy::arch::requires_recurrence;

// Rollout geometry + hyperparameters, mirroring python/compile/aot.py and
// model.py (the Python↔Rust contract for the PJRT path; the native path
// keeps the same numbers so runs are comparable across backends).
pub const HIDDEN: usize = 128;
pub const B_FWD: usize = 16;
pub const B_ROLL: usize = 32;
pub const HORIZON: usize = 32;
pub const GAMMA: f32 = 0.99;
pub const LAM: f32 = 0.95;

const CLIP: f32 = 0.2;
const VF_COEF: f32 = 0.5;
const MAX_GRAD_NORM: f32 = 0.5;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Flat parameter count for the *default* (flat-observation) model
/// architecture — the legacy formula, kept as the Python↔Rust n_params
/// cross-check. Arbitrary architectures: [`ResolvedPolicy::n_params`].
pub fn n_params(obs_dim: usize, act_dims: &[usize], hidden: usize, lstm: bool) -> usize {
    let mut spec = PolicySpec::default().with_hidden(hidden);
    if lstm {
        spec = spec.with_lstm(hidden);
    }
    ResolvedPolicy::from_flat(&spec, obs_dim, act_dims).n_params()
}

/// Borrowed views of each parameter leaf inside the flat vector, laid
/// out by [`ResolvedPolicy::ranges`]. Weights are row-major
/// `(fan_in, fan_out)`; embedding tables are `(vocab, embed_dim)`.
struct ParamView<'a> {
    actor_b: &'a [f32],
    actor_w: &'a [f32],
    critic_b: &'a [f32],
    critic_w: &'a [f32],
    embeds: Vec<&'a [f32]>,
    enc1_b: &'a [f32],
    enc1_w: &'a [f32],
    enc2_b: &'a [f32],
    enc2_w: &'a [f32],
    lstm_b: &'a [f32],
    lstm_w: &'a [f32],
}

impl<'a> ParamView<'a> {
    fn split(p: &'a [f32], arch: &ResolvedPolicy) -> Result<ParamView<'a>> {
        let r = arch.ranges();
        ensure!(
            p.len() == r.total,
            "params len {} != expected {} for architecture '{}'",
            p.len(),
            r.total,
            arch.spec.key()
        );
        Ok(ParamView {
            actor_b: &p[r.actor_b],
            actor_w: &p[r.actor_w],
            critic_b: &p[r.critic_b],
            critic_w: &p[r.critic_w],
            embeds: r.embeds.iter().map(|e| &p[e.clone()]).collect(),
            enc1_b: &p[r.enc1_b],
            enc1_w: &p[r.enc1_w],
            enc2_b: &p[r.enc2_b],
            enc2_w: &p[r.enc2_w],
            lstm_b: &p[r.lstm_b],
            lstm_w: &p[r.lstm_w],
        })
    }
}

// ---------------------------------------------------------------------------
// Dense kernels now live in `backend/kernels/` (the ref.py
// `linear_act_ref` contract, row-major): the bit-exact scalar flavors
// moved there verbatim as `gemm::*_scalar`, alongside the lane-tiled
// SIMD flavors. The `k_*` dispatch methods on [`NativeBackend`] pick a
// flavor per the backend's [`KernelPath`].

/// libm tanh over a block — the scalar path's elementwise activation.
fn tanh_inplace(xs: &mut [f32]) {
    for x in xs {
        *x = x.tanh();
    }
}

/// libm sigmoid — the scalar path's gate activation.
#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

// ---------------------------------------------------------------------------
// Shared PPO loss: per-slot softmax statistics, the clipped surrogate,
// and its gradient w.r.t. logits/values — identical math for the
// feedforward and BPTT paths (model._ppo_loss).

/// Returns `(metrics, d_logits, d_value)` over `n` flattened sample rows.
/// `metrics = [loss, pg_loss, v_loss, entropy, approx_kl]`.
///
/// Generic over the exp/ln provider: `StdMath` monomorphizes to the
/// exact libm call sequence the scalar kernel path is pinned to;
/// `FastMath` is the vectorizable polynomial flavor the SIMD path uses.
#[allow(clippy::too_many_arguments)]
fn ppo_loss_grads<M: ScalarMath>(
    act_dims: &[usize],
    logits: &[f32],
    values: &[f32],
    actions: &[i32],
    old_logp: &[f32],
    adv: &[f32],
    ret: &[f32],
    ent_coef: f32,
    norm_adv: bool,
    n: usize,
) -> Result<([f32; 5], Vec<f32>, Vec<f32>)> {
    let a: usize = act_dims.iter().sum();
    let slots = act_dims.len();
    let nf = n as f32;

    // Per-slot softmax statistics: probs, log-probs, slot entropies.
    let mut probs = vec![0.0f32; n * a];
    let mut lps = vec![0.0f32; n * a];
    let mut slot_ent = vec![0.0f32; n * slots];
    let mut logp = vec![0.0f32; n];
    let mut entropy = vec![0.0f32; n];
    for i in 0..n {
        let row = &logits[i * a..(i + 1) * a];
        let mut off = 0;
        for (s, &k) in act_dims.iter().enumerate() {
            let seg = &row[off..off + k];
            let mx = seg.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for &x in seg {
                z += M::exp(x - mx);
            }
            let logz = M::ln(z) + mx;
            let mut hs = 0.0f32;
            for (j, &x) in seg.iter().enumerate() {
                let lp = x - logz;
                let p = M::exp(lp);
                lps[i * a + off + j] = lp;
                probs[i * a + off + j] = p;
                hs -= p * lp;
            }
            let act = actions[i * slots + s] as usize;
            ensure!(act < k, "action {act} out of range for slot {s} (dim {k})");
            logp[i] += lps[i * a + off + act];
            slot_ent[i * slots + s] = hs;
            entropy[i] += hs;
            off += k;
        }
    }

    // Clipped-surrogate loss (model._ppo_loss). Advantages are
    // normalized over *this* batch when `norm_adv` — i.e. per minibatch
    // once the trainer splits the segment.
    let (mu, sd) = if norm_adv {
        let mu = adv.iter().sum::<f32>() / nf;
        let var = adv.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / nf;
        (mu, var.sqrt())
    } else {
        (0.0, 1.0)
    };
    let mut pg_loss = 0.0f32;
    let mut v_loss = 0.0f32;
    let mut ent_mean = 0.0f32;
    let mut kl = 0.0f32;
    let mut g_logp = vec![0.0f32; n]; // d pg_loss / d logp_i
    let mut d_value = vec![0.0f32; n];
    for i in 0..n {
        let advn = if norm_adv {
            (adv[i] - mu) / (sd + 1e-8)
        } else {
            adv[i]
        };
        let logratio = logp[i] - old_logp[i];
        let ratio = M::exp(logratio);
        let clipped = ratio.clamp(1.0 - CLIP, 1.0 + CLIP);
        let pg1 = -advn * ratio;
        let pg2 = -advn * clipped;
        pg_loss += pg1.max(pg2);
        // max() routes the gradient: the clipped branch is flat
        // outside the trust region. Inside it, clipped == ratio so
        // pg1 == pg2 and this branch covers that case too.
        if pg1 >= pg2 {
            g_logp[i] = -advn * ratio / nf;
        }
        v_loss += 0.5 * (values[i] - ret[i]) * (values[i] - ret[i]);
        d_value[i] = VF_COEF * (values[i] - ret[i]) / nf;
        ent_mean += entropy[i];
        kl += (ratio - 1.0) - logratio;
    }
    pg_loss /= nf;
    v_loss /= nf;
    ent_mean /= nf;
    kl /= nf;
    let loss = pg_loss - ent_coef * ent_mean + VF_COEF * v_loss;

    // d loss / d logits: policy-gradient term + entropy-bonus term.
    let mut d_logits = vec![0.0f32; n * a];
    for i in 0..n {
        let mut off = 0;
        for (s, &k) in act_dims.iter().enumerate() {
            let act = actions[i * slots + s] as usize;
            let hs = slot_ent[i * slots + s];
            for j in 0..k {
                let p = probs[i * a + off + j];
                let lp = lps[i * a + off + j];
                let onehot = if j == act { 1.0 } else { 0.0 };
                d_logits[i * a + off + j] =
                    g_logp[i] * (onehot - p) + (ent_coef / nf) * p * (lp + hs);
            }
            off += k;
        }
    }

    Ok(([loss, pg_loss, v_loss, ent_mean, kl], d_logits, d_value))
}

// ---------------------------------------------------------------------------

/// The pure-Rust compute backend (see module docs).
#[derive(Clone)]
pub struct NativeBackend {
    key: String,
    spec: SpecManifest,
    arch: ResolvedPolicy,
    rng: Rng,
    /// Which kernel flavor the `k_*` dispatchers route to. Defaults to
    /// [`KernelPath::Simd`]; set `train.kernels = "scalar"` for the
    /// bit-exact reference path.
    path: KernelPath,
    /// Worker-thread budget for kernel fork-join (`PUFFER_KERNEL_THREADS`).
    threads: usize,
    /// Reusable forward-pass activations for the `*_into` entry points —
    /// the serve hot path's allocation-free batched forwards.
    fwd: FwdScratch,
}

/// Reusable activation buffers for [`NativeBackend::forward_into`] /
/// [`NativeBackend::forward_lstm_into`]: resized (never reallocated at
/// steady state) per call, fully overwritten by the kernels.
#[derive(Clone, Default)]
struct FwdScratch {
    h1: Vec<f32>,
    x: Vec<f32>,
    gates: Vec<f32>,
}

impl NativeBackend {
    /// Build a backend for a first-party env with its **default**
    /// architecture ([`PolicySpec::default_for`] — feedforward, except
    /// recurrent reference envs, which get the LSTM sandwich).
    pub fn for_env(env_name: &str, env: &dyn FlatEnv) -> Result<Self> {
        Self::for_env_with_policy(env_name, env, &PolicySpec::default_for(env_name))
    }

    /// Build a backend for an env with an explicit [`PolicySpec`]: the
    /// spec's per-leaf encoders are resolved against the env's emulated
    /// observation layout, and the architecture key fragment is embedded
    /// in the backend/checkpoint key (relative to the env's default
    /// spec, so default-arch checkpoints keep their pre-PolicySpec
    /// keys).
    ///
    /// `env_name` may be a full [`EnvSpec`](crate::wrappers::EnvSpec)
    /// key ("ocean/squared+clip_reward=1+stack=4"); wrapper fragments
    /// become part of the key, and `env` is expected to be the *wrapped*
    /// probe so the spec is sized from the wrapped geometry.
    pub fn for_env_with_policy(
        env_name: &str,
        env: &dyn FlatEnv,
        policy: &PolicySpec,
    ) -> Result<Self> {
        // A feedforward policy cannot solve a memory task — fail at
        // construction instead of burning the step budget training
        // garbage. (The *default* spec for such envs is recurrent; this
        // only fires when a user explicitly forces feedforward.)
        ensure!(
            policy.is_recurrent() || !requires_recurrence(env_name),
            "'{env_name}' needs a recurrent (LSTM) policy to be solvable, but \
             this PolicySpec is feedforward — training would produce ~chance \
             scores. Drop the override (the default spec for this env is \
             recurrent) or set --policy.lstm=true."
        );
        let agents = env.num_agents();
        ensure!(
            B_ROLL % agents == 0,
            "env '{env_name}': batch_roll {B_ROLL} not divisible by {agents} agents"
        );
        let arch = ResolvedPolicy::resolve(policy, env.obs_layout(), env.action_dims())?;
        let spec = SpecManifest {
            obs_dim: arch.obs_dim,
            n_params: arch.n_params(),
            act_dims: arch.act_dims.clone(),
            agents,
            lstm: arch.is_recurrent(),
            hidden: arch.hidden(),
            policy: arch.effective_spec(),
            batch_fwd: B_FWD,
            batch_roll: B_ROLL,
            horizon: HORIZON,
            gamma: GAMMA as f64,
            lam: LAM as f64,
            params0: String::new(),
            artifacts: BTreeMap::new(),
        };
        let mut key = Manifest::spec_key_for_env(env_name);
        if let Some(frag) = arch.key_fragment(&PolicySpec::default_for(env_name)) {
            key.push('#');
            key.push_str(&frag);
        }
        // Deterministic per-spec init, like aot.py's name-hashed params0
        // (the architecture fragment participates, so distinct archs
        // draw distinct initial weights).
        let seed = key
            .bytes()
            .fold(0x4E41_5449u64, |acc, b| acc.wrapping_mul(131).wrapping_add(b as u64));
        Self::from_arch(key, spec, arch, seed)
    }

    /// Build from an explicit manifest spec (tests, custom geometries,
    /// manifest-driven paths): the architecture is taken from
    /// `spec.policy` over the opaque flat observation — no layout, so no
    /// per-leaf embedding resolution (see
    /// [`from_arch`](Self::from_arch) for that).
    ///
    /// # Panics
    ///
    /// If `spec` is internally inconsistent — `n_params` / `lstm` /
    /// `hidden` disagreeing with what `spec.policy` resolves to. That is
    /// a caller-constructed contradiction, not an input condition; use
    /// [`from_arch`](Self::from_arch) for fallible construction.
    pub fn from_spec(key: String, spec: SpecManifest, seed: u64) -> Self {
        let arch = ResolvedPolicy::from_flat(&spec.policy, spec.obs_dim, &spec.act_dims);
        Self::from_arch(key, spec, arch, seed)
            .unwrap_or_else(|e| panic!("from_spec: manifest contradicts its own policy spec: {e}"))
    }

    /// Build from a fully resolved architecture (golden-fixture tests,
    /// embedded-leaf specs with explicit geometry).
    pub fn from_arch(
        key: String,
        spec: SpecManifest,
        arch: ResolvedPolicy,
        seed: u64,
    ) -> Result<Self> {
        ensure!(
            spec.n_params == arch.n_params(),
            "spec '{key}': manifest n_params {} != resolved architecture {} ('{}')",
            spec.n_params,
            arch.n_params(),
            arch.spec.key()
        );
        ensure!(
            spec.obs_dim == arch.obs_dim && spec.act_dims == arch.act_dims,
            "spec '{key}': manifest geometry disagrees with resolved architecture"
        );
        ensure!(
            spec.lstm == arch.is_recurrent(),
            "spec '{key}': manifest lstm flag disagrees with the architecture"
        );
        Ok(NativeBackend {
            key,
            spec,
            arch,
            rng: Rng::new(seed),
            path: KernelPath::default(),
            threads: kernels::thread_cap_from_env(),
            fwd: FwdScratch::default(),
        })
    }

    /// The resolved architecture this backend executes.
    pub fn arch(&self) -> &ResolvedPolicy {
        &self.arch
    }

    /// Select the kernel flavor (`train.kernels`): `Scalar` is the
    /// bit-exact reference path, `Simd` (default) the lane-tiled
    /// multithreaded path.
    pub fn set_kernel_path(&mut self, path: KernelPath) {
        self.path = path;
    }

    /// The kernel flavor this backend dispatches to.
    pub fn kernel_path(&self) -> KernelPath {
        self.path
    }

    /// Override the kernel worker-thread budget (test hook for the
    /// thread-count-invariance pins; runs resolve it from
    /// `PUFFER_KERNEL_THREADS` at construction).
    pub fn set_kernel_threads(&mut self, n: usize) {
        self.threads = n.clamp(1, 64);
    }

    // -- kernel dispatch ----------------------------------------------------

    fn k_linear(&self, x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        match self.path {
            KernelPath::Scalar => gemm::linear_scalar(x, w, b, out, m, k, n),
            KernelPath::Simd => gemm::linear_simd(x, w, b, out, m, k, n, self.threads),
        }
    }

    fn k_accum_at_b(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        match self.path {
            KernelPath::Scalar => gemm::accum_at_b_scalar(a, b, out, m, k, n),
            KernelPath::Simd => gemm::accum_at_b_simd(a, b, out, m, k, n, self.threads),
        }
    }

    fn k_matmul_a_wt(&self, a: &[f32], w: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
        match self.path {
            KernelPath::Scalar => gemm::matmul_a_wt_scalar(a, w, out, m, n, k),
            KernelPath::Simd => gemm::matmul_a_wt_simd(a, w, out, m, n, k, self.threads),
        }
    }

    fn k_tanh(&self, xs: &mut [f32]) {
        match self.path {
            KernelPath::Scalar => tanh_inplace(xs),
            KernelPath::Simd => kernels::elementwise::tanh_block(xs),
        }
    }

    /// PPO loss + grads with the path's exp/ln flavor.
    fn k_loss(
        &self,
        logits: &[f32],
        values: &[f32],
        batch: &TrainBatch<'_>,
        ent_coef: f32,
        n: usize,
    ) -> Result<([f32; 5], Vec<f32>, Vec<f32>)> {
        match self.path {
            KernelPath::Scalar => ppo_loss_grads::<StdMath>(
                &self.arch.act_dims,
                logits,
                values,
                batch.actions,
                batch.logp,
                batch.adv,
                batch.ret,
                ent_coef,
                batch.norm_adv,
                n,
            ),
            KernelPath::Simd => ppo_loss_grads::<FastMath>(
                &self.arch.act_dims,
                logits,
                values,
                batch.actions,
                batch.logp,
                batch.adv,
                batch.ret,
                ent_coef,
                batch.norm_adv,
                n,
            ),
        }
    }

    /// Global-norm clip + Adam with the path's flavor (the scalar
    /// free function below, or the banded deterministic SIMD update).
    fn k_adam(&self, params: &mut [f32], opt: &mut AdamState, lr: f32, grads: &[f32]) {
        match self.path {
            KernelPath::Scalar => adam_update(params, opt, lr, grads),
            KernelPath::Simd => {
                opt.step += 1.0;
                kernels::adam::adam_update_simd(
                    params,
                    &mut opt.m,
                    &mut opt.v,
                    grads,
                    opt.step,
                    lr,
                    ADAM_B1,
                    ADAM_B2,
                    ADAM_EPS,
                    MAX_GRAD_NORM,
                    self.threads,
                );
            }
        }
    }

    /// Build the trunk input for `rows` observations: raw segments pass
    /// through, token segments are replaced by embedding-table rows.
    /// Returns the trunk (borrowed when nothing is embedded — the
    /// default path stays zero-copy) plus the clamped token indices per
    /// embed segment (kept for the backward scatter).
    fn trunk_input<'a>(
        &self,
        pv: &ParamView<'_>,
        obs: &'a [f32],
        rows: usize,
    ) -> (Cow<'a, [f32]>, Vec<Vec<usize>>) {
        if !self.arch.has_embeds() {
            return (Cow::Borrowed(obs), Vec::new());
        }
        let d = self.arch.obs_dim;
        let ti = self.arch.trunk_in;
        let dim = self.arch.spec.embed_dim;
        let mut trunk = vec![0.0f32; rows * ti];
        let mut tokens: Vec<Vec<usize>> = Vec::new();
        let mut col = 0usize;
        let mut ei = 0usize;
        for seg in &self.arch.segments {
            match *seg {
                TrunkSegment::Raw { offset, count, .. } => {
                    for i in 0..rows {
                        trunk[i * ti + col..i * ti + col + count]
                            .copy_from_slice(&obs[i * d + offset..i * d + offset + count]);
                    }
                    col += count;
                }
                TrunkSegment::Embed {
                    offset,
                    count,
                    vocab,
                    base,
                    ..
                } => {
                    let table = pv.embeds[ei];
                    let mut toks = Vec::with_capacity(rows * count);
                    for i in 0..rows {
                        for j in 0..count {
                            let v = obs[i * d + offset + j];
                            let t = ((v.round() as i64) - base as i64)
                                .clamp(0, vocab as i64 - 1) as usize;
                            trunk[i * ti + col + j * dim..i * ti + col + (j + 1) * dim]
                                .copy_from_slice(&table[t * dim..(t + 1) * dim]);
                            toks.push(t);
                        }
                    }
                    tokens.push(toks);
                    ei += 1;
                    col += count * dim;
                }
            }
        }
        (Cow::Owned(trunk), tokens)
    }

    /// Scatter `d_trunk` (`rows × trunk_in`) into the embedding-table
    /// gradients — the backward half of [`trunk_input`](Self::trunk_input).
    fn scatter_embed_grads(
        &self,
        d_trunk: &[f32],
        tokens: &[Vec<usize>],
        rows: usize,
        grads: &mut [f32],
        ranges: &ArchRanges,
    ) {
        let ti = self.arch.trunk_in;
        let dim = self.arch.spec.embed_dim;
        let mut col = 0usize;
        let mut ei = 0usize;
        for seg in &self.arch.segments {
            match seg {
                TrunkSegment::Raw { count, .. } => col += count,
                TrunkSegment::Embed { count, .. } => {
                    let g = &mut grads[ranges.embeds[ei].clone()];
                    let toks = &tokens[ei];
                    for i in 0..rows {
                        for j in 0..*count {
                            let t = toks[i * count + j];
                            let c0 = i * ti + col + j * dim;
                            let src = &d_trunk[c0..c0 + dim];
                            for (o, &v) in g[t * dim..(t + 1) * dim].iter_mut().zip(src) {
                                *o += v;
                            }
                        }
                    }
                    col += count * dim;
                    ei += 1;
                }
            }
        }
    }

    /// Backward through the actor/critic heads, shared by both train
    /// paths: accumulates head parameter gradients and **overwrites**
    /// `d_hidden` with `d_logits @ actor_wᵀ + d_value ⊗ critic_w`
    /// (`rows × decode_in`).
    #[allow(clippy::too_many_arguments)]
    fn head_backward(
        &self,
        pv: &ParamView<'_>,
        ranges: &ArchRanges,
        hidden: &[f32],
        d_logits: &[f32],
        d_value: &[f32],
        rows: usize,
        grads: &mut [f32],
        d_hidden: &mut [f32],
    ) {
        let (d_in, a) = (self.arch.decode_in(), self.arch.act_sum());
        for i in 0..rows {
            for j in 0..a {
                grads[ranges.actor_b.start + j] += d_logits[i * a + j];
            }
            grads[ranges.critic_b.start] += d_value[i];
        }
        self.k_accum_at_b(hidden, d_logits, &mut grads[ranges.actor_w.clone()], rows, d_in, a);
        for i in 0..rows {
            let dv = d_value[i];
            if dv != 0.0 {
                for kk in 0..d_in {
                    grads[ranges.critic_w.start + kk] += hidden[i * d_in + kk] * dv;
                }
            }
        }
        self.k_matmul_a_wt(d_logits, pv.actor_w, d_hidden, rows, a, d_in);
        for i in 0..rows {
            let dv = d_value[i];
            for kk in 0..d_in {
                d_hidden[i * d_in + kk] += dv * pv.critic_w[kk];
            }
        }
    }

    /// Backward through the trunk — tanh' through enc2, enc2 grads,
    /// tanh' through enc1, enc1 grads, and the embedding scatter — shared
    /// verbatim by the feedforward path and every BPTT step. `d_top` is
    /// the loss gradient w.r.t. the trunk output `x` (`rows × hidden`);
    /// scratch buffers in `s` are resized (not reallocated) per call.
    #[allow(clippy::too_many_arguments)]
    fn trunk_backward(
        &self,
        pv: &ParamView<'_>,
        ranges: &ArchRanges,
        d_top: &[f32],
        x: &[f32],
        h1: &[f32],
        trunk: &[f32],
        tokens: &[Vec<usize>],
        rows: usize,
        grads: &mut [f32],
        s: &mut TrunkBwdScratch,
    ) {
        let (h, ti) = (self.arch.hidden(), self.arch.trunk_in);
        s.d_z2.resize(rows * h, 0.0);
        s.d_z2.copy_from_slice(d_top);
        for (dz, &hv) in s.d_z2.iter_mut().zip(x) {
            *dz *= 1.0 - hv * hv;
        }
        self.k_accum_at_b(h1, &s.d_z2, &mut grads[ranges.enc2_w.clone()], rows, h, h);
        for i in 0..rows {
            for j in 0..h {
                grads[ranges.enc2_b.start + j] += s.d_z2[i * h + j];
            }
        }
        s.d_h1.resize(rows * h, 0.0);
        self.k_matmul_a_wt(&s.d_z2, pv.enc2_w, &mut s.d_h1, rows, h, h);
        s.d_z1.resize(rows * h, 0.0);
        s.d_z1.copy_from_slice(&s.d_h1);
        for (dz, &hv) in s.d_z1.iter_mut().zip(h1) {
            *dz *= 1.0 - hv * hv;
        }
        self.k_accum_at_b(trunk, &s.d_z1, &mut grads[ranges.enc1_w.clone()], rows, ti, h);
        for i in 0..rows {
            for j in 0..h {
                grads[ranges.enc1_b.start + j] += s.d_z1[i * h + j];
            }
        }
        if self.arch.has_embeds() {
            s.d_trunk.resize(rows * ti, 0.0);
            self.k_matmul_a_wt(&s.d_z1, pv.enc1_w, &mut s.d_trunk, rows, h, ti);
            self.scatter_embed_grads(&s.d_trunk, tokens, rows, grads, ranges);
        }
    }

    /// Two-layer tanh trunk (model.py `encode`) over a prepared trunk
    /// input, into caller buffers (resized, then fully overwritten by
    /// the linear kernels). `h1` is kept for backprop, `x` feeds the
    /// decoder or LSTM cell.
    fn encode_into(
        &self,
        pv: &ParamView<'_>,
        trunk: &[f32],
        rows: usize,
        h1: &mut Vec<f32>,
        x: &mut Vec<f32>,
    ) {
        let (ti, h) = (self.arch.trunk_in, self.arch.hidden());
        h1.resize(rows * h, 0.0);
        self.k_linear(trunk, pv.enc1_w, pv.enc1_b, h1, rows, ti, h);
        self.k_tanh(h1);
        x.resize(rows * h, 0.0);
        self.k_linear(h1, pv.enc2_w, pv.enc2_b, x, rows, h, h);
        self.k_tanh(x);
    }

    /// Allocating wrapper over [`encode_into`](Self::encode_into) for
    /// the train paths (which keep the activations anyway).
    fn encode(&self, pv: &ParamView<'_>, trunk: &[f32], rows: usize) -> (Vec<f32>, Vec<f32>) {
        let mut h1 = Vec::new();
        let mut x = Vec::new();
        self.encode_into(pv, trunk, rows, &mut h1, &mut x);
        (h1, x)
    }

    /// Actor/critic heads off a hidden state (model.py `decode`), into
    /// caller buffers.
    fn decode_into(
        &self,
        pv: &ParamView<'_>,
        hidden: &[f32],
        rows: usize,
        logits: &mut Vec<f32>,
        values: &mut Vec<f32>,
    ) {
        let (d_in, a) = (self.arch.decode_in(), self.arch.act_sum());
        logits.resize(rows * a, 0.0);
        self.k_linear(hidden, pv.actor_w, pv.actor_b, logits, rows, d_in, a);
        values.resize(rows, 0.0);
        self.k_linear(hidden, pv.critic_w, pv.critic_b, values, rows, d_in, 1);
    }

    /// Allocating wrapper over [`decode_into`](Self::decode_into).
    fn decode(&self, pv: &ParamView<'_>, hidden: &[f32], rows: usize) -> (Vec<f32>, Vec<f32>) {
        let mut logits = Vec::new();
        let mut values = Vec::new();
        self.decode_into(pv, hidden, rows, &mut logits, &mut values);
        (logits, values)
    }

    /// One fused-gate LSTM cell step into caller buffers: `gates =
    /// [x, h] @ w + b`, split `(i, f, g, o)`; `gates` ends up holding
    /// the post-activation gate values (kept for BPTT). The scalar path
    /// materializes the `[x, h]` concat exactly like the reference; the
    /// SIMD path runs the fused cell kernel.
    #[allow(clippy::too_many_arguments)]
    fn lstm_cell_into(
        &self,
        pv: &ParamView<'_>,
        x: &[f32],
        h_in: &[f32],
        c_in: &[f32],
        rows: usize,
        gates: &mut Vec<f32>,
        h_out: &mut Vec<f32>,
        c_out: &mut Vec<f32>,
    ) {
        let (h, sd) = (self.arch.hidden(), self.arch.state_dim());
        gates.resize(rows * 4 * sd, 0.0);
        h_out.resize(rows * sd, 0.0);
        c_out.resize(rows * sd, 0.0);
        match self.path {
            KernelPath::Scalar => {
                let mut xh = vec![0.0; rows * (h + sd)];
                for r in 0..rows {
                    xh[r * (h + sd)..r * (h + sd) + h].copy_from_slice(&x[r * h..(r + 1) * h]);
                    xh[r * (h + sd) + h..(r + 1) * (h + sd)]
                        .copy_from_slice(&h_in[r * sd..(r + 1) * sd]);
                }
                gemm::linear_scalar(&xh, pv.lstm_w, pv.lstm_b, gates, rows, h + sd, 4 * sd);
                for r in 0..rows {
                    let g = &mut gates[r * 4 * sd..(r + 1) * 4 * sd];
                    for j in 0..sd {
                        let i_g = sigmoid(g[j]);
                        let f_g = sigmoid(g[sd + j]);
                        let g_g = g[2 * sd + j].tanh();
                        let o_g = sigmoid(g[3 * sd + j]);
                        let c = f_g * c_in[r * sd + j] + i_g * g_g;
                        c_out[r * sd + j] = c;
                        h_out[r * sd + j] = o_g * c.tanh();
                        g[j] = i_g;
                        g[sd + j] = f_g;
                        g[2 * sd + j] = g_g;
                        g[3 * sd + j] = o_g;
                    }
                }
            }
            KernelPath::Simd => kernels::lstm::cell_simd(
                x,
                h_in,
                c_in,
                pv.lstm_w,
                pv.lstm_b,
                gates,
                h_out,
                c_out,
                rows,
                h,
                sd,
                self.threads,
            ),
        }
    }

    /// Allocating wrapper over [`lstm_cell_into`](Self::lstm_cell_into):
    /// returns `(h', c', gates_post)`.
    fn lstm_cell(
        &self,
        pv: &ParamView<'_>,
        x: &[f32],
        h_in: &[f32],
        c_in: &[f32],
        rows: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut gates = Vec::new();
        let mut h2 = Vec::new();
        let mut c2 = Vec::new();
        self.lstm_cell_into(pv, x, h_in, c_in, rows, &mut gates, &mut h2, &mut c2);
        (h2, c2, gates)
    }

    // -- train paths -------------------------------------------------------

    /// Feedforward PPO update over `n = T × R` flattened sample rows.
    fn train_step_ff(
        &mut self,
        params: &mut Vec<f32>,
        opt: &mut AdamState,
        lr: f32,
        ent_coef: f32,
        batch: &TrainBatch<'_>,
    ) -> Result<[f32; 5]> {
        let h = self.arch.hidden();
        let n = batch.t * batch.r;
        let pv = ParamView::split(params, &self.arch)?;
        let (trunk, tokens) = self.trunk_input(&pv, batch.obs, n);
        let (h1, h2) = self.encode(&pv, &trunk, n);
        let (logits, values) = self.decode(&pv, &h2, n);

        let (metrics, d_logits, d_value) = self.k_loss(&logits, &values, batch, ent_coef, n)?;

        // Backprop through decode + trunk into one flat gradient vector
        // (the same `ranges` layout the forward pass reads from). The
        // chain is shared with the BPTT path: heads, then tanh' through
        // enc2/enc1, then the embedding scatter. For feedforward archs
        // the decode input *is* the trunk output, so `d_h2` feeds
        // `trunk_backward` directly.
        let mut grads = vec![0.0f32; params.len()];
        let ranges = self.arch.ranges();
        let mut d_h2 = vec![0.0f32; n * h];
        self.head_backward(&pv, &ranges, &h2, &d_logits, &d_value, n, &mut grads, &mut d_h2);
        let mut scratch = TrunkBwdScratch::default();
        self.trunk_backward(
            &pv,
            &ranges,
            &d_h2,
            &h2,
            &h1,
            &trunk,
            &tokens,
            n,
            &mut grads,
            &mut scratch,
        );
        drop(pv);

        self.k_adam(params, opt, lr, &grads);
        Ok(metrics)
    }

    /// Recurrent PPO update: BPTT through the whole `(T, R)` time scan,
    /// with LSTM state zeroed at episode starts (`batch.starts`) exactly
    /// like `model.py::train_step_lstm` — the scan begins from zero
    /// state each segment, and the minibatch slicer only ever hands this
    /// path whole agent rows, so the time structure is intact.
    fn train_step_bptt(
        &mut self,
        params: &mut Vec<f32>,
        opt: &mut AdamState,
        lr: f32,
        ent_coef: f32,
        batch: &TrainBatch<'_>,
    ) -> Result<[f32; 5]> {
        let (t_dim, rows) = (batch.t, batch.r);
        let n = t_dim * rows;
        let h = self.arch.hidden();
        let sd = self.arch.state_dim();
        let d = self.arch.obs_dim;
        let a = self.arch.act_sum();
        let pv = ParamView::split(params, &self.arch)?;

        // ---- forward scan, caching per-step activations ----
        struct StepCache {
            trunk: Option<Vec<f32>>, // None when borrowed straight from obs
            tokens: Vec<Vec<usize>>,
            h1: Vec<f32>,
            x: Vec<f32>,
            h_in: Vec<f32>, // post-mask state entering the cell
            c_in: Vec<f32>,
            gates: Vec<f32>, // post-activation (i, f, g, o)
            c: Vec<f32>,
            h: Vec<f32>,
        }
        let mut cache: Vec<StepCache> = Vec::with_capacity(t_dim);
        let mut logits_all = vec![0.0f32; n * a];
        let mut values_all = vec![0.0f32; n];
        let mut h_prev = vec![0.0f32; rows * sd];
        let mut c_prev = vec![0.0f32; rows * sd];
        for t in 0..t_dim {
            let obs_t = &batch.obs[t * rows * d..(t + 1) * rows * d];
            let starts_t = &batch.starts[t * rows..(t + 1) * rows];
            let mut h_in = h_prev.clone();
            let mut c_in = c_prev.clone();
            for r in 0..rows {
                if starts_t[r] != 0.0 {
                    h_in[r * sd..(r + 1) * sd].fill(0.0);
                    c_in[r * sd..(r + 1) * sd].fill(0.0);
                }
            }
            let (trunk, tokens) = self.trunk_input(&pv, obs_t, rows);
            let (h1, x) = self.encode(&pv, &trunk, rows);
            let (h2, c2, gates) = self.lstm_cell(&pv, &x, &h_in, &c_in, rows);
            let (lo, va) = self.decode(&pv, &h2, rows);
            logits_all[t * rows * a..(t + 1) * rows * a].copy_from_slice(&lo);
            values_all[t * rows..(t + 1) * rows].copy_from_slice(&va);
            h_prev.copy_from_slice(&h2);
            c_prev.copy_from_slice(&c2);
            cache.push(StepCache {
                trunk: match trunk {
                    Cow::Borrowed(_) => None,
                    Cow::Owned(v) => Some(v),
                },
                tokens,
                h1,
                x,
                h_in,
                c_in,
                gates,
                c: c2,
                h: h2,
            });
        }

        // ---- loss over the flattened (T × R) rows ----
        let (metrics, d_logits, d_value) =
            self.k_loss(&logits_all, &values_all, batch, ent_coef, n)?;

        // ---- backward scan ----
        let mut grads = vec![0.0f32; params.len()];
        let ranges = self.arch.ranges();
        let mut dh_next = vec![0.0f32; rows * sd];
        let mut dc_next = vec![0.0f32; rows * sd];
        // Reused per-step scratch — sized once, overwritten every step.
        let mut dh = vec![0.0f32; rows * sd];
        let mut d_x = vec![0.0f32; rows * h];
        let mut dgates = vec![0.0f32; rows * 4 * sd];
        let mut dc_in_t = vec![0.0f32; rows * sd];
        let mut xh = vec![0.0f32; rows * (h + sd)];
        let mut d_xh = vec![0.0f32; rows * (h + sd)];
        let mut scratch = TrunkBwdScratch::default();
        for t in (0..t_dim).rev() {
            let sc = &cache[t];
            let dl = &d_logits[t * rows * a..(t + 1) * rows * a];
            let dv = &d_value[t * rows..(t + 1) * rows];
            let starts_t = &batch.starts[t * rows..(t + 1) * rows];

            // Heads off h_t: parameter grads + dh, then the carry from
            // t+1 on top.
            self.head_backward(&pv, &ranges, &sc.h, dl, dv, rows, &mut grads, &mut dh);
            for (acc, &carry) in dh.iter_mut().zip(&dh_next) {
                *acc += carry;
            }

            // Cell backward: c = f∘c_in + i∘g, h = o∘tanh(c).
            for r in 0..rows {
                let g = &sc.gates[r * 4 * sd..(r + 1) * 4 * sd];
                for j in 0..sd {
                    let (gi, gf, gg, go) = (g[j], g[sd + j], g[2 * sd + j], g[3 * sd + j]);
                    let c = sc.c[r * sd + j];
                    let tc = c.tanh();
                    let dh_v = dh[r * sd + j];
                    let d_o = dh_v * tc;
                    let dc = dh_v * go * (1.0 - tc * tc) + dc_next[r * sd + j];
                    let d_i = dc * gg;
                    let d_f = dc * sc.c_in[r * sd + j];
                    let d_g = dc * gi;
                    dc_in_t[r * sd + j] = dc * gf;
                    dgates[r * 4 * sd + j] = d_i * gi * (1.0 - gi);
                    dgates[r * 4 * sd + sd + j] = d_f * gf * (1.0 - gf);
                    dgates[r * 4 * sd + 2 * sd + j] = d_g * (1.0 - gg * gg);
                    dgates[r * 4 * sd + 3 * sd + j] = d_o * go * (1.0 - go);
                }
            }
            // lstm parameter grads off [x, h_in].
            for r in 0..rows {
                xh[r * (h + sd)..r * (h + sd) + h].copy_from_slice(&sc.x[r * h..(r + 1) * h]);
                xh[r * (h + sd) + h..(r + 1) * (h + sd)]
                    .copy_from_slice(&sc.h_in[r * sd..(r + 1) * sd]);
            }
            for i in 0..rows {
                for j in 0..4 * sd {
                    grads[ranges.lstm_b.start + j] += dgates[i * 4 * sd + j];
                }
            }
            self.k_accum_at_b(
                &xh,
                &dgates,
                &mut grads[ranges.lstm_w.clone()],
                rows,
                h + sd,
                4 * sd,
            );
            // d_xh = dgates @ lstm_wᵀ → split into d_x and d_h_in.
            self.k_matmul_a_wt(&dgates, pv.lstm_w, &mut d_xh, rows, 4 * sd, h + sd);
            for r in 0..rows {
                d_x[r * h..(r + 1) * h].copy_from_slice(&d_xh[r * (h + sd)..r * (h + sd) + h]);
            }

            // Trunk backward: identical chain to the feedforward path.
            let obs_t = &batch.obs[t * rows * d..(t + 1) * rows * d];
            let trunk_t: &[f32] = match &sc.trunk {
                Some(v) => v,
                None => obs_t,
            };
            self.trunk_backward(
                &pv,
                &ranges,
                &d_x,
                &sc.x,
                &sc.h1,
                trunk_t,
                &sc.tokens,
                rows,
                &mut grads,
                &mut scratch,
            );

            // Carry to t-1 through the episode-start mask: state entering
            // step t was `h_{t-1} * (1 - starts_t)`.
            for r in 0..rows {
                let mask = 1.0 - starts_t[r];
                for j in 0..sd {
                    dh_next[r * sd + j] = d_xh[r * (h + sd) + h + j] * mask;
                    dc_next[r * sd + j] = dc_in_t[r * sd + j] * mask;
                }
            }
        }
        drop(pv);

        self.k_adam(params, opt, lr, &grads);
        Ok(metrics)
    }

    // -- allocation-free forward entry points (serve hot path) -------------

    /// [`PolicyBackend::forward`] into a caller-owned [`Forward`],
    /// reusing the backend's activation scratch — zero steady-state
    /// allocations, the serve batcher's per-batch entry point.
    pub fn forward_into(
        &mut self,
        params: &[f32],
        obs: &[f32],
        rows: usize,
        out: &mut Forward,
    ) -> Result<()> {
        let d = self.arch.obs_dim;
        ensure!(
            !self.arch.is_recurrent(),
            "stateless forward on a recurrent architecture — use forward_lstm"
        );
        ensure!(obs.len() == rows * d, "obs len {} != {rows}x{d}", obs.len());
        let pv = ParamView::split(params, &self.arch)?;
        let mut fs = std::mem::take(&mut self.fwd);
        let (trunk, _) = self.trunk_input(&pv, obs, rows);
        self.encode_into(&pv, &trunk, rows, &mut fs.h1, &mut fs.x);
        self.decode_into(&pv, &fs.x, rows, &mut out.logits, &mut out.values);
        drop(pv);
        self.fwd = fs;
        Ok(())
    }

    /// [`PolicyBackend::forward_lstm`] into a caller-owned
    /// [`ForwardLstm`], reusing the backend's activation scratch.
    pub fn forward_lstm_into(
        &mut self,
        params: &[f32],
        obs: &[f32],
        h_in: &[f32],
        c_in: &[f32],
        rows: usize,
        out: &mut ForwardLstm,
    ) -> Result<()> {
        let d = self.arch.obs_dim;
        let sd = self.arch.state_dim();
        ensure!(sd > 0, "forward_lstm on a feedforward architecture");
        ensure!(obs.len() == rows * d, "obs len {} != {rows}x{d}", obs.len());
        ensure!(
            h_in.len() == rows * sd && c_in.len() == rows * sd,
            "state shape mismatch"
        );
        let pv = ParamView::split(params, &self.arch)?;
        let mut fs = std::mem::take(&mut self.fwd);
        let (trunk, _) = self.trunk_input(&pv, obs, rows);
        self.encode_into(&pv, &trunk, rows, &mut fs.h1, &mut fs.x);
        self.lstm_cell_into(&pv, &fs.x, h_in, c_in, rows, &mut fs.gates, &mut out.h, &mut out.c);
        self.decode_into(&pv, &out.h, rows, &mut out.logits, &mut out.values);
        drop(pv);
        self.fwd = fs;
        Ok(())
    }
}

/// Reusable scratch for [`NativeBackend::trunk_backward`]: one set of
/// buffers per train step, resized (never reallocated) per call.
#[derive(Default)]
struct TrunkBwdScratch {
    d_z2: Vec<f32>,
    d_h1: Vec<f32>,
    d_z1: Vec<f32>,
    d_trunk: Vec<f32>,
}

/// Global-norm clip + Adam (model._adam, flat) — shared update tail.
fn adam_update(params: &mut [f32], opt: &mut AdamState, lr: f32, grads: &[f32]) {
    let gnorm = (grads.iter().map(|g| g * g).sum::<f32>() + 1e-12).sqrt();
    let scale = (MAX_GRAD_NORM / gnorm).min(1.0);
    opt.step += 1.0;
    let bc1 = 1.0 - ADAM_B1.powf(opt.step);
    let bc2 = 1.0 - ADAM_B2.powf(opt.step);
    for i in 0..params.len() {
        let g = grads[i] * scale;
        opt.m[i] = ADAM_B1 * opt.m[i] + (1.0 - ADAM_B1) * g;
        opt.v[i] = ADAM_B2 * opt.v[i] + (1.0 - ADAM_B2) * g * g;
        let mhat = opt.m[i] / bc1;
        let vhat = opt.v[i] / bc2;
        params[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
}

impl PolicyBackend for NativeBackend {
    fn spec(&self) -> &SpecManifest {
        &self.spec
    }

    fn key(&self) -> &str {
        &self.key
    }

    fn init_params(&mut self) -> Result<Vec<f32>> {
        // CleanRL-style layer_init scaling, as model.init_params: weights
        // are N(0, scale²/fan_in), biases zero, actor head scaled 0.01,
        // embedding tables bias-free. Draw order == layout order, so the
        // default architecture replays the exact pre-PolicySpec stream.
        let arch = self.arch.clone();
        let (h, a, d_in, sd, ti) = (
            arch.hidden(),
            arch.act_sum(),
            arch.decode_in(),
            arch.state_dim(),
            arch.trunk_in,
        );
        let mut p = Vec::with_capacity(self.spec.n_params);
        let dense = |rng: &mut Rng,
                     p: &mut Vec<f32>,
                     fan_in: usize,
                     fan_out: usize,
                     scale: f32,
                     bias: bool| {
            if bias {
                p.extend(std::iter::repeat(0.0).take(fan_out));
            }
            let s = scale / (fan_in as f32).sqrt();
            p.extend((0..fan_in * fan_out).map(|_| rng.normal() as f32 * s));
        };
        dense(&mut self.rng, &mut p, d_in, a, 0.01, true); // actor
        dense(&mut self.rng, &mut p, d_in, 1, 1.0, true); // critic
        for seg in &arch.segments {
            if let TrunkSegment::Embed { vocab, .. } = seg {
                dense(&mut self.rng, &mut p, *vocab, arch.spec.embed_dim, 1.0, false);
            }
        }
        dense(&mut self.rng, &mut p, ti, h, 1.0, true); // enc1
        dense(&mut self.rng, &mut p, h, h, 1.0, true); // enc2
        if sd > 0 {
            dense(&mut self.rng, &mut p, h + sd, 4 * sd, 1.0, true);
        }
        ensure!(
            p.len() == self.spec.n_params,
            "init_params produced {} values, spec says {}",
            p.len(),
            self.spec.n_params
        );
        Ok(p)
    }

    fn forward(&mut self, params: &[f32], obs: &[f32], rows: usize) -> Result<Forward> {
        let mut out = Forward::default();
        self.forward_into(params, obs, rows, &mut out)?;
        Ok(out)
    }

    fn forward_lstm(
        &mut self,
        params: &[f32],
        obs: &[f32],
        h_in: &[f32],
        c_in: &[f32],
        rows: usize,
    ) -> Result<ForwardLstm> {
        let mut out = ForwardLstm::default();
        self.forward_lstm_into(params, obs, h_in, c_in, rows, &mut out)?;
        Ok(out)
    }

    fn gae(
        &mut self,
        rewards: &[f32],
        values: &[f32],
        dones: &[f32],
        last_values: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        // The ref.py `gae_ref` reverse scan, time-major (T, R).
        let (t_dim, r_dim) = (self.spec.horizon, self.spec.batch_roll);
        let n = t_dim * r_dim;
        ensure!(
            rewards.len() == n && values.len() == n && dones.len() == n,
            "gae inputs must be (T={t_dim}, R={r_dim})"
        );
        ensure!(last_values.len() == r_dim, "last_values must be R={r_dim}");
        let (gamma, lam) = (self.spec.gamma as f32, self.spec.lam as f32);

        let mut adv = vec![0.0f32; n];
        let mut gae = vec![0.0f32; r_dim];
        let mut next_value = last_values.to_vec();
        for t in (0..t_dim).rev() {
            let base = t * r_dim;
            for r in 0..r_dim {
                let mask = 1.0 - dones[base + r];
                let delta = rewards[base + r] + gamma * next_value[r] * mask - values[base + r];
                gae[r] = delta + gamma * lam * mask * gae[r];
                adv[base + r] = gae[r];
                next_value[r] = values[base + r];
            }
        }
        let ret: Vec<f32> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
        Ok((adv, ret))
    }

    fn train_step(
        &mut self,
        params: &mut Vec<f32>,
        opt: &mut AdamState,
        lr: f32,
        ent_coef: f32,
        batch: &TrainBatch<'_>,
    ) -> Result<[f32; 5]> {
        let d = self.arch.obs_dim;
        let slots = self.arch.act_dims.len();
        let n = batch.t * batch.r;
        ensure!(batch.obs.len() == n * d, "obs len {} != {n}x{d}", batch.obs.len());
        ensure!(batch.actions.len() == n * slots, "actions len mismatch");
        ensure!(
            batch.logp.len() == n && batch.adv.len() == n && batch.ret.len() == n,
            "logp/adv/ret must be N={n}"
        );
        ensure!(batch.starts.len() == n, "starts must be N={n}");
        ensure!(
            opt.m.len() == params.len() && opt.v.len() == params.len(),
            "optimizer state length mismatch"
        );
        if self.arch.is_recurrent() {
            self.train_step_bptt(params, opt, lr, ent_coef, batch)
        } else {
            self.train_step_ff(params, opt, lr, ent_coef, batch)
        }
    }

    fn fork_for_rollout(&self) -> Result<Box<dyn PolicyBackend>> {
        // The backend is pure math over caller-owned parameters; its only
        // state (the init RNG) is never touched by forward passes, so a
        // plain clone is a safe concurrent-inference fork.
        Ok(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_manifest(policy: &PolicySpec, d: usize, act_dims: Vec<usize>) -> SpecManifest {
        let arch = ResolvedPolicy::from_flat(policy, d, &act_dims);
        SpecManifest {
            obs_dim: d,
            n_params: arch.n_params(),
            act_dims,
            agents: 1,
            lstm: policy.is_recurrent(),
            hidden: policy.hidden,
            policy: policy.clone(),
            batch_fwd: 4,
            batch_roll: 4,
            horizon: 3,
            gamma: 0.99,
            lam: 0.95,
            params0: String::new(),
            artifacts: BTreeMap::new(),
        }
    }

    fn tiny_spec(d: usize, act_dims: Vec<usize>, hidden: usize) -> SpecManifest {
        tiny_manifest(&PolicySpec::default().with_hidden(hidden), d, act_dims)
    }

    #[test]
    fn init_params_matches_spec_len() {
        let mut b = NativeBackend::from_spec("t".into(), tiny_spec(5, vec![3, 2], 8), 1);
        let p = b.init_params().unwrap();
        assert_eq!(p.len(), b.spec().n_params);
        // Actor bias and all biases start at zero; some weights nonzero.
        assert!(p[..5].iter().all(|&x| x == 0.0), "actor bias zero-init");
        assert!(p.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let mut b = NativeBackend::from_spec("t".into(), tiny_spec(5, vec![3, 2], 8), 2);
        let p = b.init_params().unwrap();
        let obs: Vec<f32> = (0..4 * 5).map(|i| (i as f32 * 0.37).sin()).collect();
        let out = b.forward(&p, &obs, 4).unwrap();
        assert_eq!(out.logits.len(), 4 * 5);
        assert_eq!(out.values.len(), 4);
        assert!(out.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn gae_single_row_hand_check() {
        // T=3, R=1, gamma/lam as spec; verify against a hand-unrolled scan.
        let mut spec = tiny_spec(1, vec![2], 4);
        spec.horizon = 3;
        spec.batch_roll = 1;
        let mut b = NativeBackend::from_spec("t".into(), spec, 3);
        let rewards = [1.0f32, 0.0, 2.0];
        let values = [0.5f32, 0.4, 0.3];
        let dones = [0.0f32, 1.0, 0.0];
        let last = [0.7f32];
        let (adv, ret) = b.gae(&rewards, &values, &dones, &last).unwrap();
        let (g, l) = (0.99f32, 0.95f32);
        let d2 = 2.0 + g * 0.7 - 0.3;
        let a2 = d2;
        let d1 = 0.0 + 0.0 - 0.4; // done masks the bootstrap
        let a1 = d1;
        let d0 = 1.0 + g * 0.4 - 0.5;
        let a0 = d0 + g * l * a1;
        assert!((adv[0] - a0).abs() < 1e-6, "{} vs {a0}", adv[0]);
        assert!((adv[1] - a1).abs() < 1e-6);
        assert!((adv[2] - a2).abs() < 1e-6);
        assert!((ret[2] - (a2 + 0.3)).abs() < 1e-6);
    }

    type RegressionBatch = (Vec<f32>, Vec<i32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>);

    fn value_regression_batch(t: usize, r: usize, d: usize) -> RegressionBatch {
        let n = t * r;
        (
            (0..n * d).map(|i| ((i * 7 % 13) as f32) / 13.0).collect(),
            vec![0i32; n],
            vec![-0.69f32; n],
            vec![0.0f32; n],
            (0..n).map(|i| (i % 3) as f32).collect(),
            vec![0.0; n],
        )
    }

    #[test]
    fn train_step_descends_on_value_loss() {
        // With adv ≡ 0 the update is pure value regression: repeated steps
        // must reduce v_loss.
        let mut b = NativeBackend::from_spec("t".into(), tiny_spec(3, vec![2], 8), 4);
        let mut params = b.init_params().unwrap();
        let mut opt = AdamState::new(params.len());
        let (t, r) = (3usize, 4usize);
        let (obs, actions, logp, adv, ret, starts) = value_regression_batch(t, r, 3);
        let batch = TrainBatch {
            t,
            r,
            norm_adv: true,
            obs: &obs,
            starts: &starts,
            actions: &actions,
            logp: &logp,
            adv: &adv,
            ret: &ret,
        };
        let first = b.train_step(&mut params, &mut opt, 0.05, 0.0, &batch).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = b.train_step(&mut params, &mut opt, 0.05, 0.0, &batch).unwrap();
        }
        assert!(
            last[2] < first[2] * 0.5,
            "v_loss did not descend: {} -> {}",
            first[2],
            last[2]
        );
        assert_eq!(opt.step, 61.0);
    }

    #[test]
    fn bptt_train_step_descends_on_value_loss() {
        // The recurrent path must optimize too: same pure value
        // regression through the LSTM sandwich, with episode starts
        // scattered through the batch.
        let policy = PolicySpec::default().with_hidden(8).with_lstm(8);
        let mut b = NativeBackend::from_spec("t".into(), tiny_manifest(&policy, 3, vec![2]), 4);
        let mut params = b.init_params().unwrap();
        let mut opt = AdamState::new(params.len());
        let (t, r) = (3usize, 4usize);
        let (obs, actions, logp, adv, ret, mut starts) = value_regression_batch(t, r, 3);
        for (i, s) in starts.iter_mut().enumerate() {
            *s = if i % 5 == 0 { 1.0 } else { 0.0 };
        }
        let batch = TrainBatch {
            t,
            r,
            norm_adv: true,
            obs: &obs,
            starts: &starts,
            actions: &actions,
            logp: &logp,
            adv: &adv,
            ret: &ret,
        };
        let first = b.train_step(&mut params, &mut opt, 0.05, 0.0, &batch).unwrap();
        let mut last = first;
        for _ in 0..80 {
            last = b.train_step(&mut params, &mut opt, 0.05, 0.0, &batch).unwrap();
        }
        assert!(
            last[2] < first[2] * 0.5,
            "BPTT v_loss did not descend: {} -> {}",
            first[2],
            last[2]
        );
    }

    #[test]
    fn recurrent_reference_env_gets_a_recurrent_default_arch() {
        // ocean/memory now constructs on the native backend: the default
        // PolicySpec for it carries the LSTM stage (and no architecture
        // key fragment — it *is* the env default).
        let env = crate::envs::make("ocean/memory", 0);
        let b = NativeBackend::for_env("ocean/memory", env.as_ref()).unwrap();
        assert!(b.arch().is_recurrent());
        assert!(b.spec().lstm);
        assert_eq!(b.key(), "ocean_memory");
        // Forcing feedforward on a memory env stays a hard, actionable
        // construction error.
        let err = NativeBackend::for_env_with_policy(
            "ocean/memory",
            env.as_ref(),
            &PolicySpec::default(),
        )
        .err()
        .expect("feedforward override must not construct")
        .to_string();
        assert!(err.contains("--policy.lstm"), "unactionable error: {err}");
        assert!(requires_recurrence("ocean/memory+clip_reward=1"));
        assert!(!requires_recurrence("ocean/bandit"));
    }

    #[test]
    fn non_default_arch_is_part_of_the_key() {
        let env = crate::envs::make("ocean/bandit", 0);
        let b = NativeBackend::for_env("ocean/bandit", env.as_ref()).unwrap();
        assert_eq!(b.key(), "ocean_bandit");
        let b64 = NativeBackend::for_env_with_policy(
            "ocean/bandit",
            env.as_ref(),
            &PolicySpec::default().with_hidden(64),
        )
        .unwrap();
        assert_eq!(b64.key(), "ocean_bandit#h=64");
        // Distinct architecture keys draw distinct init streams.
        let lstm = NativeBackend::for_env_with_policy(
            "ocean/bandit",
            env.as_ref(),
            &PolicySpec::default().with_lstm(128),
        )
        .unwrap();
        assert_eq!(lstm.key(), "ocean_bandit#lstm=128");
    }

    #[test]
    fn norm_adv_off_feeds_raw_advantages() {
        // Constant positive advantages: normalized they collapse to zero
        // (zero policy gradient); raw they drive an actor update. The two
        // settings must therefore diverge from the same start.
        let mk = || NativeBackend::from_spec("t".into(), tiny_spec(3, vec![2], 8), 9);
        let mut b = mk();
        let params0 = b.init_params().unwrap();
        let t = 3usize;
        let r = 4usize;
        let n = t * r;
        let obs: Vec<f32> = (0..n * 3).map(|i| ((i * 5 % 11) as f32) / 11.0).collect();
        let actions = vec![1i32; n];
        let logp = vec![-0.69f32; n];
        let adv = vec![1.0f32; n];
        let ret = vec![0.0f32; n];
        let starts = vec![0.0f32; n];
        let run = |norm_adv: bool| {
            let mut b = mk();
            let mut params = params0.clone();
            let mut opt = AdamState::new(params.len());
            let batch = TrainBatch {
                t,
                r,
                norm_adv,
                obs: &obs,
                starts: &starts,
                actions: &actions,
                logp: &logp,
                adv: &adv,
                ret: &ret,
            };
            let m = b.train_step(&mut params, &mut opt, 0.01, 0.0, &batch).unwrap();
            (params, m)
        };
        let (p_norm, m_norm) = run(true);
        let (p_raw, m_raw) = run(false);
        assert!((m_norm[1]).abs() < 1e-6, "normalized constant adv → pg 0");
        assert!(m_raw[1].abs() > 1e-3, "raw adv must drive the surrogate");
        assert_ne!(p_norm, p_raw);
    }

    #[test]
    fn fork_for_rollout_matches_forward() {
        let mut b = NativeBackend::from_spec("t".into(), tiny_spec(5, vec![3], 8), 2);
        let p = b.init_params().unwrap();
        let obs: Vec<f32> = (0..4 * 5).map(|i| (i as f32 * 0.31).cos()).collect();
        let mut fork = b.fork_for_rollout().unwrap();
        assert_eq!(fork.key(), b.key());
        let a = b.forward(&p, &obs, 4).unwrap();
        let f = fork.forward(&p, &obs, 4).unwrap();
        assert_eq!(a.logits, f.logits);
        assert_eq!(a.values, f.values);
    }

    #[test]
    fn embedded_tokens_change_the_trunk_not_the_api() {
        use crate::spaces::Space;
        // {feat: f32[2], tok: Discrete(5)} with embed_dim 3.
        let space = Space::dict(vec![
            ("feat".into(), Space::boxf(&[2], -1.0, 1.0)),
            ("tok".into(), Space::Discrete(5)),
        ]);
        let policy = PolicySpec::default().with_hidden(8).with_embed_dim(3);
        let arch = ResolvedPolicy::resolve(&policy, &space.layout(), &[2]).unwrap();
        let mut spec = tiny_manifest(&policy, 3, vec![2]);
        spec.hidden = 8;
        spec.n_params = arch.n_params();
        let mut b = NativeBackend::from_arch("t".into(), spec, arch, 7).unwrap();
        let params = b.init_params().unwrap();
        // Two observations differing only in the token must produce
        // different logits (the table rows differ), same shapes.
        let obs_a = [0.5f32, -0.25, 1.0, 0.5f32, -0.25, 3.0];
        let out = b.forward(&params, &obs_a, 2).unwrap();
        assert_eq!(out.logits.len(), 2 * 2);
        assert_ne!(out.logits[0..2], out.logits[2..4]);
        // Out-of-range tokens clamp instead of indexing out of bounds.
        let obs_c = [0.5f32, -0.25, 99.0];
        assert!(b.forward(&params, &obs_c, 1).is_ok());
    }
}
