//! The compute-backend layer: one trait, [`PolicyBackend`], between the
//! coordinator (trainer + rollout + policy) and whatever executes the
//! learner math.
//!
//! Two implementations ship:
//!
//! - [`NativeBackend`] (default) — a pure-Rust port of the reference math
//!   in `python/compile/kernels/ref.py` / `gae.py` and `model.py`, built
//!   from a resolved [`PolicySpec`](crate::policy::PolicySpec): per-leaf
//!   observation encoders (raw or embedding tables), the trunk MLP
//!   forward, the LSTM cell **and full BPTT training**, the GAE reverse
//!   scan, and the full clipped-surrogate PPO update (hand-derived
//!   backprop + global-norm clip + Adam). Zero native dependencies: the
//!   crate builds and trains on a clean machine with no XLA artifacts
//!   and no Python.
//! - `PjrtBackend` (`pjrt` cargo feature) — the original AOT path: JAX/
//!   Pallas entry points lowered to HLO text by `python/compile/aot.py`
//!   and executed through the PJRT C API. Executes default architectures
//!   only (the shapes are baked into the artifacts).
//!
//! Both speak the same flat-parameter contract (the alphabetical
//! `ravel_pytree` order of `model.py`), so checkpoints written against
//! one backend restore against the other **when the resolved
//! architectures match** — [`crate::train::Trainer::restore`] rejects
//! mismatched architecture keys and parameter counts. Golden-value
//! parity between the two is pinned by `crates/puffer-train/tests/native_parity.rs`
//! against fixtures generated from the JAX reference
//! (`python/compile/gen_fixtures.py`).

pub mod kernels;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use kernels::KernelPath;
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use crate::runtime::SpecManifest;
use anyhow::Result;

/// Output of a feedforward policy pass over `rows` observations.
#[derive(Clone, Debug, Default)]
pub struct Forward {
    /// `rows × sum(act_dims)` logits, row-major.
    pub logits: Vec<f32>,
    /// `rows` value estimates.
    pub values: Vec<f32>,
}

/// Output of a recurrent (one LSTM cell step) policy pass.
#[derive(Clone, Debug, Default)]
pub struct ForwardLstm {
    pub logits: Vec<f32>,
    pub values: Vec<f32>,
    /// Updated hidden state, `rows × hidden`.
    pub h: Vec<f32>,
    /// Updated cell state, `rows × hidden`.
    pub c: Vec<f32>,
}

/// Flat Adam optimizer state (same length as the parameter vector).
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
}

impl AdamState {
    pub fn new(n_params: usize) -> Self {
        AdamState {
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
            step: 0.0,
        }
    }
}

/// One PPO update's worth of rollout data, time-major `(T, R)` over all
/// agent rows — a full segment, or a row-subset minibatch produced by
/// [`TrainBatch::gather_rows`]. Feedforward backends flatten to
/// `N = T × R` sample rows; recurrent backends keep the time structure
/// (and the `starts` episode boundaries) for BPTT.
pub struct TrainBatch<'a> {
    /// Rollout segment length `T`.
    pub t: usize,
    /// Agent rows `R` in this batch (`batch_roll`, or
    /// `batch_roll / minibatches` for a minibatch view).
    pub r: usize,
    /// Normalize advantages (mean/var over *this* batch — i.e. per
    /// minibatch once the segment is split) inside the surrogate loss.
    pub norm_adv: bool,
    /// `(T, R, obs_dim)` f32.
    pub obs: &'a [f32],
    /// `(T, R)`: 1.0 where the stored obs begins a new episode.
    pub starts: &'a [f32],
    /// `(T, R, slots)` i32.
    pub actions: &'a [i32],
    /// `(T, R)` behavior log-probs.
    pub logp: &'a [f32],
    /// `(T, R)` advantages (from [`PolicyBackend::gae`]).
    pub adv: &'a [f32],
    /// `(T, R)` returns.
    pub ret: &'a [f32],
}

/// Reusable owned storage backing a minibatch view gathered out of a full
/// `(T, R)` segment — one allocation, recycled across minibatches and
/// epochs.
#[derive(Default)]
pub struct MinibatchScratch {
    obs: Vec<f32>,
    starts: Vec<f32>,
    actions: Vec<i32>,
    logp: Vec<f32>,
    adv: Vec<f32>,
    ret: Vec<f32>,
}

impl TrainBatch<'_> {
    /// Gather the row subset `rows` (indices into `0..self.r`) into
    /// `scratch`, returning a dense time-major `(T, rows.len())` batch.
    ///
    /// Minibatching slices **whole rows**: each selected agent row keeps
    /// its full `T`-step trajectory and its `starts` episode-boundary
    /// flags, so recurrent (BPTT) backends see intact time structure —
    /// shuffling permutes rows, never time steps (LSTM-start-aware
    /// slicing).
    pub fn gather_rows<'s>(
        &self,
        rows: &[usize],
        scratch: &'s mut MinibatchScratch,
    ) -> TrainBatch<'s> {
        let (t_dim, r_dim) = (self.t, self.r);
        let n = t_dim * r_dim;
        let d = self.obs.len() / n;
        let slots = self.actions.len() / n;
        let rb = rows.len();
        debug_assert!(rows.iter().all(|&g| g < r_dim), "row index out of range");

        scratch.obs.resize(t_dim * rb * d, 0.0);
        scratch.starts.resize(t_dim * rb, 0.0);
        scratch.actions.resize(t_dim * rb * slots, 0);
        scratch.logp.resize(t_dim * rb, 0.0);
        scratch.adv.resize(t_dim * rb, 0.0);
        scratch.ret.resize(t_dim * rb, 0.0);
        for ti in 0..t_dim {
            for (j, &g) in rows.iter().enumerate() {
                let src = ti * r_dim + g;
                let dst = ti * rb + j;
                scratch.obs[dst * d..(dst + 1) * d]
                    .copy_from_slice(&self.obs[src * d..(src + 1) * d]);
                scratch.actions[dst * slots..(dst + 1) * slots]
                    .copy_from_slice(&self.actions[src * slots..(src + 1) * slots]);
                scratch.starts[dst] = self.starts[src];
                scratch.logp[dst] = self.logp[src];
                scratch.adv[dst] = self.adv[src];
                scratch.ret[dst] = self.ret[src];
            }
        }
        TrainBatch {
            t: t_dim,
            r: rb,
            norm_adv: self.norm_adv,
            obs: &scratch.obs,
            starts: &scratch.starts,
            actions: &scratch.actions,
            logp: &scratch.logp,
            adv: &scratch.adv,
            ret: &scratch.ret,
        }
    }
}

/// The narrow waist between the trainer/policy and the learner math:
/// policy forward, value head, GAE, and the PPO update.
///
/// Parameters travel as one opaque flat f32 vector owned by the caller
/// (the [`Policy`](crate::policy::Policy) / the trainer); backends define
/// its layout via [`PolicyBackend::init_params`] and consume it
/// everywhere else.
pub trait PolicyBackend: Send {
    /// The shape contract this backend was built for.
    fn spec(&self) -> &SpecManifest;

    /// Spec key, e.g. `"ocean_bandit"` (checkpoint compatibility).
    fn key(&self) -> &str;

    /// Produce the initial flat parameter vector (`spec().n_params` long).
    fn init_params(&mut self) -> Result<Vec<f32>>;

    /// Feedforward pass: `obs` is `rows × obs_dim` f32, row-major.
    fn forward(&mut self, params: &[f32], obs: &[f32], rows: usize) -> Result<Forward>;

    /// Recurrent pass: one LSTM cell step with per-row state `h`, `c`
    /// (`rows × hidden` each).
    fn forward_lstm(
        &mut self,
        params: &[f32],
        obs: &[f32],
        h: &[f32],
        c: &[f32],
        rows: usize,
    ) -> Result<ForwardLstm>;

    /// Generalized Advantage Estimation over the `(T, R)` rollout
    /// (`horizon × batch_roll` from the spec). Returns
    /// `(advantages, returns)`, both `(T, R)`.
    fn gae(
        &mut self,
        rewards: &[f32],
        values: &[f32],
        dones: &[f32],
        last_values: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)>;

    /// One clipped-surrogate PPO update, applied in place to `params` and
    /// `opt`. Returns `[loss, pg_loss, v_loss, entropy, approx_kl]`.
    fn train_step(
        &mut self,
        params: &mut Vec<f32>,
        opt: &mut AdamState,
        lr: f32,
        ent_coef: f32,
        batch: &TrainBatch<'_>,
    ) -> Result<[f32; 5]>;

    /// Clone this backend for concurrent rollout inference on the
    /// pipelined trainer's collector thread (only `forward`/`forward_lstm`
    /// are called on the fork; the learner keeps `self` for
    /// `gae`/`train_step`). Backends whose execution state cannot run
    /// concurrently keep this default error — the serial path
    /// (`pipeline.depth = 0`) never calls it.
    fn fork_for_rollout(&self) -> Result<Box<dyn PolicyBackend>> {
        anyhow::bail!(
            "backend '{}' does not support pipelined collection \
             (train.pipeline.depth > 0); use the serial trainer \
             (--pipeline.depth=0)",
            self.key()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    type SeqBatch = (Vec<f32>, Vec<f32>, Vec<i32>, Vec<f32>, Vec<f32>, Vec<f32>);

    fn seq_batch(t: usize, r: usize, d: usize, slots: usize) -> SeqBatch {
        let n = t * r;
        (
            (0..n * d).map(|i| i as f32).collect(),
            (0..n).map(|i| (i % 3 == 0) as u8 as f32).collect(),
            (0..n * slots).map(|i| i as i32).collect(),
            (0..n).map(|i| -(i as f32)).collect(),
            (0..n).map(|i| 0.5 * i as f32).collect(),
            (0..n).map(|i| 2.0 * i as f32).collect(),
        )
    }

    #[test]
    fn gather_rows_keeps_time_major_layout() {
        let (t, r, d, slots) = (3, 4, 2, 2);
        let (obs, starts, actions, logp, adv, ret) = seq_batch(t, r, d, slots);
        let full = TrainBatch {
            t,
            r,
            norm_adv: true,
            obs: &obs,
            starts: &starts,
            actions: &actions,
            logp: &logp,
            adv: &adv,
            ret: &ret,
        };
        let mut scratch = MinibatchScratch::default();
        let mb = full.gather_rows(&[2, 0], &mut scratch);
        assert_eq!((mb.t, mb.r), (3, 2));
        assert!(mb.norm_adv);
        for ti in 0..t {
            for (j, g) in [2usize, 0].into_iter().enumerate() {
                let src = ti * r + g;
                let dst = ti * 2 + j;
                assert_eq!(mb.obs[dst * d..(dst + 1) * d], obs[src * d..(src + 1) * d]);
                assert_eq!(
                    mb.actions[dst * slots..(dst + 1) * slots],
                    actions[src * slots..(src + 1) * slots]
                );
                assert_eq!(mb.starts[dst], starts[src]);
                assert_eq!(mb.logp[dst], logp[src]);
                assert_eq!(mb.adv[dst], adv[src]);
                assert_eq!(mb.ret[dst], ret[src]);
            }
        }
    }

    #[test]
    fn gather_all_rows_in_order_is_identity() {
        let (t, r, d, slots) = (2, 3, 1, 1);
        let (obs, starts, actions, logp, adv, ret) = seq_batch(t, r, d, slots);
        let full = TrainBatch {
            t,
            r,
            norm_adv: false,
            obs: &obs,
            starts: &starts,
            actions: &actions,
            logp: &logp,
            adv: &adv,
            ret: &ret,
        };
        let mut scratch = MinibatchScratch::default();
        let mb = full.gather_rows(&[0, 1, 2], &mut scratch);
        assert_eq!(mb.obs, &obs[..]);
        assert_eq!(mb.starts, &starts[..]);
        assert_eq!(mb.actions, &actions[..]);
        assert_eq!(mb.logp, &logp[..]);
        assert_eq!(mb.adv, &adv[..]);
        assert_eq!(mb.ret, &ret[..]);
    }
}
