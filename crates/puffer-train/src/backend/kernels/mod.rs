//! Vectorized, multithreaded compute kernels for the native backend.
//!
//! This module is the Rust analogue of the Pallas kernel tree under
//! `python/compile/kernels/`: cache-blocked GEMM microkernels tiled to
//! an 8-wide f32 lane ([`LANES`], the AVX2/NEON-friendly width LLVM
//! auto-vectorizes hand-unrolled `[f32; 8]` arithmetic into), a fused
//! LSTM cell (one pass for all four gates), branch-free fast
//! transcendentals, and a deterministic Adam update.
//!
//! ## Kernel paths
//!
//! Every kernel exists in two flavors behind [`KernelPath`]:
//!
//! - [`KernelPath::Scalar`] — the original, bit-exact transcription of
//!   `ref.py` / `model.py`. Pinned by the golden fixtures and every
//!   bit-identity test in the repo; byte-for-byte the pre-kernel math.
//! - [`KernelPath::Simd`] (default) — lane-tiled microkernels with
//!   structured fork-join row parallelism. Validated against the scalar
//!   path and the fixtures at explicit tolerances
//!   (`crates/puffer-train/tests/kernel_parity.rs`).
//!
//! ## Determinism
//!
//! Parallelism never introduces nondeterminism: threads partition
//! **output** elements only — each output row is computed by exactly one
//! thread running the identical sequential reduction, so results are
//! invariant to the thread count (`PUFFER_KERNEL_THREADS=1` and `=N`
//! produce bitwise-identical floats) and to how rows are grouped into
//! batches. There are no cross-thread reductions, no atomics, and no
//! shared mutable state: [`for_each_row_band`] hands each scoped thread
//! a disjoint `&mut` band via `split_at_mut` and joins before returning
//! (see `CONCURRENCY.md`, "Kernel fork-join").
#![forbid(unsafe_code)]

pub mod adam;
pub mod elementwise;
pub mod gemm;
pub mod lstm;

/// The f32 lane width every microkernel tiles to. Eight lanes = one
/// AVX2 register / two NEON registers; hand-unrolled `[f32; 8]` blocks
/// reliably auto-vectorize at this width.
pub const LANES: usize = 8;

/// Minimum multiply-add count before a kernel forks worker threads.
/// Below this, `std::thread::scope` spawn/join overhead (~tens of µs)
/// outweighs the parallel speedup and the kernel runs on the calling
/// thread. 2M mul-adds ≈ 0.5 ms scalar — comfortably past break-even.
const PAR_THRESHOLD: usize = 2 << 20;

// The selector enum is plain data the spec layer parses (`train.kernels`),
// so it lives in puffer-core; re-exported here so
// `crate::backend::kernels::KernelPath` keeps resolving.
pub use puffer_core::backend::KernelPath;

/// Worker-thread budget for kernel fork-join, resolved once at backend
/// construction: `PUFFER_KERNEL_THREADS` if set (clamped to [1, 64]),
/// else the machine's available parallelism capped at 8 — GEMMs at our
/// sizes stop scaling past a handful of cores, and the trainer's
/// collector/vectorizer threads need cores too.
pub fn thread_cap_from_env() -> usize {
    if let Ok(v) = std::env::var("PUFFER_KERNEL_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 8)
}

/// How many bands to split `rows` output rows into, given the thread
/// budget and the per-row multiply-add cost. Returns 1 (run inline)
/// unless the total work clears [`PAR_THRESHOLD`]; never more bands
/// than rows. The band count depends only on (threads, rows, work) —
/// but results never depend on it at all, because bands partition
/// outputs (see module docs).
pub(crate) fn plan_bands(threads: usize, rows: usize, muladds_per_row: usize) -> usize {
    if threads <= 1 || rows == 0 {
        return 1;
    }
    let total = rows.saturating_mul(muladds_per_row);
    if total < PAR_THRESHOLD {
        return 1;
    }
    // Don't fork more bands than threshold-sized chunks of work.
    threads.min(rows).min(total / PAR_THRESHOLD + 1)
}

/// Structured fork-join over disjoint row bands of `out`: splits
/// `out` (`rows × row_w`, row-major) into `bands` contiguous bands and
/// runs `f(first_row, band_slice)` on each, on scoped threads when
/// `bands > 1`. Every band is a disjoint `&mut` (via `split_at_mut`);
/// the scope joins all threads before returning, so no reference
/// escapes and no synchronization beyond spawn/join exists.
pub(crate) fn for_each_row_band<F>(out: &mut [f32], rows: usize, row_w: usize, bands: usize, f: &F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_w);
    if bands <= 1 || rows <= 1 {
        f(0, out);
        return;
    }
    let per = rows.div_ceil(bands);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut r0 = 0usize;
        while r0 < rows {
            let take = per.min(rows - r0);
            let (band, tail) = rest.split_at_mut(take * row_w);
            rest = tail;
            let first = r0;
            // The calling thread takes the final band itself instead of
            // sitting idle in join.
            if r0 + take >= rows {
                f(first, band);
            } else {
                s.spawn(move || f(first, band));
            }
            r0 += take;
        }
    });
}

/// Load an 8-lane block starting at `off`. The caller guarantees
/// `off + LANES <= s.len()`; the bounds are checked once here rather
/// than per lane, which is what lets LLVM keep the block in one vector
/// register.
#[inline(always)]
pub(crate) fn load8(s: &[f32], off: usize) -> [f32; 8] {
    let mut v = [0.0f32; 8];
    v.copy_from_slice(&s[off..off + 8]);
    v
}

/// Store an 8-lane block starting at `off`.
#[inline(always)]
pub(crate) fn store8(s: &mut [f32], off: usize, v: [f32; 8]) {
    s[off..off + 8].copy_from_slice(&v);
}

/// `acc += a * b` over 8 lanes (fused multiply-add shape).
#[inline(always)]
pub(crate) fn fma8(acc: &mut [f32; 8], a: f32, b: [f32; 8]) {
    for l in 0..8 {
        acc[l] += a * b[l];
    }
}

/// Fixed-order horizontal sum of 8 lanes: pairwise tree so the result
/// is independent of how many rows preceded it and identical on every
/// call with the same lanes.
#[inline(always)]
pub(crate) fn hsum8(v: [f32; 8]) -> f32 {
    let a = [v[0] + v[4], v[1] + v[5], v[2] + v[6], v[3] + v[7]];
    (a[0] + a[2]) + (a[1] + a[3])
}
