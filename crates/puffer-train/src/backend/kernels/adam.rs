//! Vectorized, deterministic global-norm-clip + Adam update.
//!
//! The update itself is embarrassingly parallel — every parameter's
//! `(m, v, p)` triple depends only on its own gradient — and each
//! element uses the exact expression sequence of the scalar
//! `adam_update`, so the banded version is bitwise identical at any
//! thread count. The only reduction is the gradient norm, computed with
//! a fixed chunking scheme ([`SUMSQ_CHUNK`]-element chunks, 8-lane
//! accumulators inside a chunk, chunks combined in ascending order) so
//! its value too is a pure function of the gradient vector.

use super::{hsum8, load8, plan_bands, LANES};

/// Chunk width for the deterministic sum-of-squares reduction: lane
/// partials are folded per chunk, chunk sums combine sequentially.
const SUMSQ_CHUNK: usize = 4096;

/// Deterministic `Σ g²` — fixed reduction tree, single-threaded (the
/// norm is O(P) against the O(P) update that follows; not worth a fork).
fn sumsq(grads: &[f32]) -> f32 {
    let mut total = 0.0f32;
    let mut c0 = 0usize;
    while c0 < grads.len() {
        let chunk = &grads[c0..(c0 + SUMSQ_CHUNK).min(grads.len())];
        let mut acc = [0.0f32; 8];
        let mut j = 0usize;
        while j + LANES <= chunk.len() {
            let g = load8(chunk, j);
            for l in 0..LANES {
                acc[l] += g[l] * g[l];
            }
            j += LANES;
        }
        let mut s = hsum8(acc);
        for &g in &chunk[j..] {
            s += g * g;
        }
        total += s;
        c0 += SUMSQ_CHUNK;
    }
    total
}

/// Global-norm clip + Adam over the flat parameter vector, row-banded
/// across threads. Semantics match the scalar `adam_update` except for
/// the norm's reduction order (tolerance-path only; the scalar kernel
/// path never calls this).
#[allow(clippy::too_many_arguments)]
pub fn adam_update_simd(
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    grads: &[f32],
    step: f32,
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
    max_norm: f32,
    threads: usize,
) {
    let n = params.len();
    debug_assert_eq!(m.len(), n);
    debug_assert_eq!(v.len(), n);
    debug_assert_eq!(grads.len(), n);
    let gnorm = (sumsq(grads) + 1e-12).sqrt();
    let scale = (max_norm / gnorm).min(1.0);
    let bc1 = 1.0 - b1.powf(step);
    let bc2 = 1.0 - b2.powf(step);

    // ~16 flops per element, counted as muladds for the fork threshold.
    let bands = plan_bands(threads, n, 16);
    if bands <= 1 {
        update_band(params, m, v, grads, 0, scale, lr, bc1, bc2, b1, b2, eps);
        return;
    }
    // Three mutable vectors band together (same shape as the LSTM cell's
    // fork-join): disjoint split_at_mut ranges, scoped spawn, join on
    // scope exit.
    let per = n.div_ceil(bands);
    std::thread::scope(|s| {
        let mut p_rest = params;
        let mut m_rest = m;
        let mut v_rest = v;
        let mut i0 = 0usize;
        while i0 < n {
            let take = per.min(n - i0);
            let (p_band, p_tail) = p_rest.split_at_mut(take);
            let (m_band, m_tail) = m_rest.split_at_mut(take);
            let (v_band, v_tail) = v_rest.split_at_mut(take);
            p_rest = p_tail;
            m_rest = m_tail;
            v_rest = v_tail;
            let first = i0;
            if i0 + take >= n {
                update_band(p_band, m_band, v_band, grads, first, scale, lr, bc1, bc2, b1, b2, eps);
            } else {
                s.spawn(move || {
                    update_band(p_band, m_band, v_band, grads, first, scale, lr, bc1, bc2, b1, b2, eps)
                });
            }
            i0 += take;
        }
    });
}

/// Elementwise Adam over one band — the scalar update expression,
/// verbatim, per element.
#[allow(clippy::too_many_arguments)]
fn update_band(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    grads: &[f32],
    first: usize,
    scale: f32,
    lr: f32,
    bc1: f32,
    bc2: f32,
    b1: f32,
    b2: f32,
    eps: f32,
) {
    for i in 0..p.len() {
        let g = grads[first + i] * scale;
        m[i] = b1 * m[i] + (1.0 - b1) * g;
        v[i] = b2 * v[i] + (1.0 - b2) * g * g;
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        p[i] -= lr * mhat / (vhat.sqrt() + eps);
    }
}
