//! Branch-free transcendentals and the activation kernels built on
//! them.
//!
//! `f32::exp` / `f32::ln` / `f32::tanh` lower to libm calls, which
//! blocks loop auto-vectorization — one function call per element. The
//! fast variants here are Cephes-style polynomial approximations
//! (range-reduce, degree-6 polynomial, reassemble the exponent via bit
//! tricks): straight-line float arithmetic LLVM can keep in vector
//! registers, accurate to ~1 ulp ×10 (worst observed ~1e-7 relative) —
//! two orders of magnitude inside the 1e-5 forward-parity tolerance the
//! SIMD path is held to.
//!
//! [`ScalarMath`] abstracts exp/ln so shared loss code (the PPO
//! surrogate in `backend/native.rs`) monomorphizes once per kernel
//! path: [`StdMath`] reproduces the scalar path bit-for-bit,
//! [`FastMath`] is the vectorizable flavor.

/// Exp/ln provider for shared loss math — dispatch by monomorphization
/// so the scalar path keeps its exact libm call sequence.
pub trait ScalarMath {
    fn exp(x: f32) -> f32;
    fn ln(x: f32) -> f32;
}

/// libm-backed math: bit-exact with the pre-kernel scalar code.
pub struct StdMath;

impl ScalarMath for StdMath {
    #[inline(always)]
    fn exp(x: f32) -> f32 {
        x.exp()
    }
    #[inline(always)]
    fn ln(x: f32) -> f32 {
        x.ln()
    }
}

/// Polynomial math: branch-free, auto-vectorizable, ~1e-7 accurate.
pub struct FastMath;

impl ScalarMath for FastMath {
    #[inline(always)]
    fn exp(x: f32) -> f32 {
        fast_exp(x)
    }
    #[inline(always)]
    fn ln(x: f32) -> f32 {
        fast_ln(x)
    }
}

// Cephes expf/logf constants (Moshier, Cephes Math Library; public
// domain coefficients). The two-part ln 2 keeps the range reduction
// exact in f32: C1 + C2 = ln 2 to double precision.
const LOG2EF: f32 = 1.442_695_04;
const EXP_C1: f32 = 0.693_359_375;
const EXP_C2: f32 = -2.121_944_4e-4;
const SQRTHF: f32 = 0.707_106_78;

/// Polynomial `e^x`. Inputs clamp to ±[87, 88] (where f32 exp
/// saturates to 0 / ~1.7e38 anyway), so the result is always finite
/// and the exponent reassembly cannot overflow. Not meaningful for
/// NaN-free code paths only in the sense that NaN propagates.
#[inline(always)]
pub fn fast_exp(x: f32) -> f32 {
    let x = x.clamp(-87.0, 88.0);
    // n = round(x / ln 2); r = x - n·ln2 in two parts (exact-ish).
    let nf = (x * LOG2EF).round();
    let r = x - nf * EXP_C1 - nf * EXP_C2;
    // Degree-6 polynomial for e^r on |r| <= ln2/2.
    let z = r * r;
    let mut p = 1.987_569_15e-4f32;
    p = p * r + 1.398_199_95e-3;
    p = p * r + 8.333_451_9e-3;
    p = p * r + 4.166_579_6e-2;
    p = p * r + 1.666_666_55e-1;
    p = p * r + 5.000_000_1e-1;
    p = p * z + r + 1.0;
    // 2^n via direct exponent-field construction: n ∈ [-126, 127].
    let scale = f32::from_bits((((nf as i32) + 127) << 23) as u32);
    p * scale
}

/// Polynomial `ln x` for normal positive floats (subnormals flush
/// through the exponent extraction; x <= 0 returns NaN). Every call
/// site feeds it softmax normalizers `z >= 1`.
#[inline(always)]
pub fn fast_ln(x: f32) -> f32 {
    if x <= 0.0 {
        return f32::NAN;
    }
    let bits = x.to_bits();
    let mut e = ((bits >> 23) as i32) - 126;
    let mut m = f32::from_bits((bits & 0x007f_ffff) | 0x3f00_0000); // [0.5, 1)
    if m < SQRTHF {
        e -= 1;
        m = m + m - 1.0;
    } else {
        m -= 1.0;
    }
    let z = m * m;
    let mut y = 7.037_683_6e-2f32;
    y = y * m - 1.151_461_03e-1;
    y = y * m + 1.167_699_87e-1;
    y = y * m - 1.242_014_08e-1;
    y = y * m + 1.424_932_28e-1;
    y = y * m - 1.666_805_77e-1;
    y = y * m + 2.000_071_48e-1;
    y = y * m - 2.499_999_4e-1;
    y = y * m + 3.333_333_1e-1;
    y = y * m * z;
    let ef = e as f32;
    y += ef * EXP_C2;
    y -= 0.5 * z;
    (m + y) + ef * EXP_C1
}

/// `tanh` via `(e^{2x} − 1)/(e^{2x} + 1)`; saturates exactly to ±1 for
/// |x| ≳ 44 thanks to the exp clamp.
#[inline(always)]
pub fn fast_tanh(x: f32) -> f32 {
    let e2 = fast_exp(2.0 * x);
    (e2 - 1.0) / (e2 + 1.0)
}

/// Logistic sigmoid via [`fast_exp`].
#[inline(always)]
pub fn fast_sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + fast_exp(-x))
}

/// In-place vectorized tanh over a block of activations.
pub fn tanh_block(xs: &mut [f32]) {
    for x in xs {
        *x = fast_tanh(*x);
    }
}
