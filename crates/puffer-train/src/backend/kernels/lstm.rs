//! Fused LSTM cell: one pass computes all four gates and applies the
//! activations, without materializing the `[x, h]` concatenation.
//!
//! The scalar path (`backend/native.rs::lstm_cell`) builds an
//! `rows × (h + sd)` concat buffer, runs one `linear` into the
//! `rows × 4·sd` gate matrix, then a second elementwise pass. Here the
//! gate GEMM streams the two input halves directly — `x` rows against
//! weight rows `0..h`, `h_in` rows against weight rows `h..h+sd` — and
//! the gate activations, cell update, and output are applied while the
//! gate row is still cache-hot. Gate layout and semantics are identical:
//! `(i, f, g, o)` blocks of `sd`, post-activation values written back
//! into `gates` for BPTT.
//!
//! Parallelism is row-banded like the GEMM family: each sample row's
//! gates/h/c are produced by exactly one thread running the same
//! sequential reduction, so results are bitwise invariant to the thread
//! count.

use super::elementwise::{fast_sigmoid, fast_tanh};
use super::{fma8, load8, plan_bands, store8, LANES};

/// One fused LSTM cell step over `rows` samples.
///
/// Inputs: `x` (`rows × h`), `h_in`/`c_in` (`rows × sd`), weights `w`
/// (`(h+sd) × 4·sd`, x-rows first), bias `b` (`4·sd`). Outputs:
/// `gates` (`rows × 4·sd`, post-activation `(i, f, g, o)`), `h_out` and
/// `c_out` (`rows × sd`).
#[allow(clippy::too_many_arguments)]
pub fn cell_simd(
    x: &[f32],
    h_in: &[f32],
    c_in: &[f32],
    w: &[f32],
    b: &[f32],
    gates: &mut [f32],
    h_out: &mut [f32],
    c_out: &mut [f32],
    rows: usize,
    h: usize,
    sd: usize,
    threads: usize,
) {
    let n = 4 * sd;
    debug_assert_eq!(x.len(), rows * h);
    debug_assert_eq!(h_in.len(), rows * sd);
    debug_assert_eq!(c_in.len(), rows * sd);
    debug_assert_eq!(w.len(), (h + sd) * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(gates.len(), rows * n);
    debug_assert_eq!(h_out.len(), rows * sd);
    debug_assert_eq!(c_out.len(), rows * sd);

    let bands = plan_bands(threads, rows, (h + sd) * n);
    if bands <= 1 {
        cell_band(x, h_in, c_in, w, b, gates, h_out, c_out, 0, rows, h, sd);
        return;
    }
    // Three outputs must band together, so this walks its own
    // split_at_mut triple instead of reusing for_each_row_band; the
    // structure is the same scoped fork-join (disjoint &mut bands, no
    // shared state, joined before return).
    let per = rows.div_ceil(bands);
    std::thread::scope(|s| {
        let mut g_rest = gates;
        let mut h_rest = h_out;
        let mut c_rest = c_out;
        let mut r0 = 0usize;
        while r0 < rows {
            let take = per.min(rows - r0);
            let (g_band, g_tail) = g_rest.split_at_mut(take * n);
            let (h_band, h_tail) = h_rest.split_at_mut(take * sd);
            let (c_band, c_tail) = c_rest.split_at_mut(take * sd);
            g_rest = g_tail;
            h_rest = h_tail;
            c_rest = c_tail;
            let first = r0;
            if r0 + take >= rows {
                cell_band(x, h_in, c_in, w, b, g_band, h_band, c_band, first, take, h, sd);
            } else {
                s.spawn(move || {
                    cell_band(x, h_in, c_in, w, b, g_band, h_band, c_band, first, take, h, sd)
                });
            }
            r0 += take;
        }
    });
}

/// The per-band body: gate GEMM row + fused activation/cell update for
/// `take` rows starting at global row `first`.
#[allow(clippy::too_many_arguments)]
fn cell_band(
    x: &[f32],
    h_in: &[f32],
    c_in: &[f32],
    w: &[f32],
    b: &[f32],
    g_band: &mut [f32],
    h_band: &mut [f32],
    c_band: &mut [f32],
    first: usize,
    take: usize,
    h: usize,
    sd: usize,
) {
    let n = 4 * sd;
    for bi in 0..take {
        let r = first + bi;
        let xrow = &x[r * h..(r + 1) * h];
        let hrow = &h_in[r * sd..(r + 1) * sd];
        let crow = &c_in[r * sd..(r + 1) * sd];
        let g = &mut g_band[bi * n..(bi + 1) * n];
        gates_row(xrow, hrow, w, b, g, h, sd);
        let ho = &mut h_band[bi * sd..(bi + 1) * sd];
        let co = &mut c_band[bi * sd..(bi + 1) * sd];
        for j in 0..sd {
            let i_g = fast_sigmoid(g[j]);
            let f_g = fast_sigmoid(g[sd + j]);
            let g_g = fast_tanh(g[2 * sd + j]);
            let o_g = fast_sigmoid(g[3 * sd + j]);
            let c = f_g * crow[j] + i_g * g_g;
            co[j] = c;
            ho[j] = o_g * fast_tanh(c);
            g[j] = i_g;
            g[sd + j] = f_g;
            g[2 * sd + j] = g_g;
            g[3 * sd + j] = o_g;
        }
    }
}

/// One pre-activation gate row: `g = b + xrow @ w[0..h] + hrow @
/// w[h..h+sd]` in 16-column panels — the [`linear_simd`]
/// microkernel shape with two stacked input segments.
///
/// [`linear_simd`]: super::gemm::linear_simd
fn gates_row(xrow: &[f32], hrow: &[f32], w: &[f32], b: &[f32], g: &mut [f32], h: usize, sd: usize) {
    let n = 4 * sd;
    let mut j = 0usize;
    while j + 2 * LANES <= n {
        let mut acc0 = load8(b, j);
        let mut acc1 = load8(b, j + LANES);
        for (kk, &a) in xrow.iter().enumerate() {
            let off = kk * n + j;
            fma8(&mut acc0, a, load8(w, off));
            fma8(&mut acc1, a, load8(w, off + LANES));
        }
        for (kk, &a) in hrow.iter().enumerate() {
            let off = (h + kk) * n + j;
            fma8(&mut acc0, a, load8(w, off));
            fma8(&mut acc1, a, load8(w, off + LANES));
        }
        store8(g, j, acc0);
        store8(g, j + LANES, acc1);
        j += 2 * LANES;
    }
    if j + LANES <= n {
        let mut acc = load8(b, j);
        for (kk, &a) in xrow.iter().enumerate() {
            fma8(&mut acc, a, load8(w, kk * n + j));
        }
        for (kk, &a) in hrow.iter().enumerate() {
            fma8(&mut acc, a, load8(w, (h + kk) * n + j));
        }
        store8(g, j, acc);
        j += LANES;
    }
    for jj in j..n {
        let mut acc = b[jj];
        for (kk, &a) in xrow.iter().enumerate() {
            acc += a * w[kk * n + jj];
        }
        for (kk, &a) in hrow.iter().enumerate() {
            acc += a * w[(h + kk) * n + jj];
        }
        g[jj] = acc;
    }
}
