//! The f32 GEMM family: `linear` (forward), `accum_at_b`
//! (weight-gradient), `matmul_a_wt` (input-gradient) — each in the
//! bit-exact scalar flavor (the `ref.py linear_act_ref` transcription,
//! moved here verbatim from `backend/native.rs`) and a lane-tiled SIMD
//! flavor.
//!
//! Tiling: the SIMD `linear` walks each output row in 16-column panels
//! (two [`LANES`]-wide accumulator blocks held in registers) with the
//! reduction dimension innermost — the classic outer-product
//! microkernel, streaming one broadcast activation against two weight
//! vectors per iteration. `accum_at_b` blocks the sample dimension in
//! [`IBLOCK`]-row tiles so the gradient source stays in L1 while a
//! band of output rows accumulates. Parallel flavors partition output
//! rows only (see the module docs on determinism): every output element
//! is produced by exactly one thread running the same sequential
//! reduction order as the single-threaded kernel.

use super::{fma8, for_each_row_band, hsum8, load8, plan_bands, store8, LANES};

/// Sample-dimension tile for [`accum_at_b_simd`]: 64 rows of a
/// 128-wide f32 gradient block is 32 KiB — an L1-resident tile.
const IBLOCK: usize = 64;

// ---------------------------------------------------------------------------
// Scalar reference kernels (bit-exact; moved verbatim from native.rs).

/// `out[m×n] = x[m×k] @ w[k×n] + b[n]` (bias broadcast over rows).
pub fn linear_scalar(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let row = &mut out[i * n..(i + 1) * n];
        row.copy_from_slice(b);
        for kk in 0..k {
            let a = x[i * k + kk];
            if a != 0.0 {
                let wrow = &w[kk * n..(kk + 1) * n];
                for (o, &wv) in row.iter_mut().zip(wrow) {
                    *o += a * wv;
                }
            }
        }
    }
}

/// `out[k×n] += a[m×k]ᵀ @ b[m×n]` (weight-gradient GEMM).
pub fn accum_at_b_scalar(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av != 0.0 {
                let brow = &b[i * n..(i + 1) * n];
                let orow = &mut out[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// `out[m×k] = a[m×n] @ w[k×n]ᵀ` (input-gradient GEMM).
pub fn matmul_a_wt_scalar(a: &[f32], w: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for (&av, &wv) in arow.iter().zip(wrow) {
                acc += av * wv;
            }
            out[i * k + kk] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// SIMD kernels.

/// Lane-tiled `out[m×n] = x[m×k] @ w[k×n] + b[n]`, row-parallel.
pub fn linear_simd(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(out.len(), m * n);
    let bands = plan_bands(threads, m, k * n);
    for_each_row_band(out, m, n, bands, &|first, band| {
        for (bi, row) in band.chunks_exact_mut(n).enumerate() {
            let i = first + bi;
            linear_row(&x[i * k..(i + 1) * k], w, b, row, k, n);
        }
    });
}

/// One output row of [`linear_simd`]: 16-column panels (two 8-lane
/// register accumulators seeded from the bias), reduction innermost,
/// then an 8-lane panel and a scalar tail for ragged widths.
#[inline]
fn linear_row(xrow: &[f32], w: &[f32], b: &[f32], row: &mut [f32], k: usize, n: usize) {
    let mut j = 0usize;
    while j + 2 * LANES <= n {
        let mut acc0 = load8(b, j);
        let mut acc1 = load8(b, j + LANES);
        for (kk, &a) in xrow.iter().enumerate().take(k) {
            let off = kk * n + j;
            fma8(&mut acc0, a, load8(w, off));
            fma8(&mut acc1, a, load8(w, off + LANES));
        }
        store8(row, j, acc0);
        store8(row, j + LANES, acc1);
        j += 2 * LANES;
    }
    if j + LANES <= n {
        let mut acc = load8(b, j);
        for (kk, &a) in xrow.iter().enumerate().take(k) {
            fma8(&mut acc, a, load8(w, kk * n + j));
        }
        store8(row, j, acc);
        j += LANES;
    }
    for jj in j..n {
        let mut acc = b[jj];
        for (kk, &a) in xrow.iter().enumerate().take(k) {
            acc += a * w[kk * n + jj];
        }
        row[jj] = acc;
    }
}

/// `acc_row += scale * src_row`, 8 lanes at a time.
#[inline(always)]
fn axpy(orow: &mut [f32], scale: f32, brow: &[f32], n: usize) {
    let mut j = 0usize;
    while j + LANES <= n {
        let mut acc = load8(orow, j);
        fma8(&mut acc, scale, load8(brow, j));
        store8(orow, j, acc);
        j += LANES;
    }
    for jj in j..n {
        orow[jj] += scale * brow[jj];
    }
}

/// Lane-tiled `out[k×n] += a[m×k]ᵀ @ b[m×n]`, parallel over the k
/// output rows. The sample dimension is tiled in [`IBLOCK`] chunks so
/// `b`'s tile stays cache-hot across a band of output rows; within one
/// output row the samples accumulate in ascending order — the same
/// order as the single-threaded kernel, whatever the band count.
pub fn accum_at_b_simd(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    let bands = plan_bands(threads, k, m * n);
    for_each_row_band(out, k, n, bands, &|first, band| {
        let mut i0 = 0usize;
        while i0 < m {
            let iend = (i0 + IBLOCK).min(m);
            for (bi, orow) in band.chunks_exact_mut(n).enumerate() {
                let kk = first + bi;
                for i in i0..iend {
                    axpy(orow, a[i * k + kk], &b[i * n..(i + 1) * n], n);
                }
            }
            i0 = iend;
        }
    });
}

/// 16-wide unrolled dot product with a fixed-order lane reduction.
#[inline]
fn dot(arow: &[f32], wrow: &[f32], n: usize) -> f32 {
    let mut acc0 = [0.0f32; 8];
    let mut acc1 = [0.0f32; 8];
    let mut j = 0usize;
    while j + 2 * LANES <= n {
        let a0 = load8(arow, j);
        let b0 = load8(wrow, j);
        let a1 = load8(arow, j + LANES);
        let b1 = load8(wrow, j + LANES);
        for l in 0..LANES {
            acc0[l] += a0[l] * b0[l];
            acc1[l] += a1[l] * b1[l];
        }
        j += 2 * LANES;
    }
    if j + LANES <= n {
        let a0 = load8(arow, j);
        let b0 = load8(wrow, j);
        for l in 0..LANES {
            acc0[l] += a0[l] * b0[l];
        }
        j += LANES;
    }
    let mut s = hsum8(acc0) + hsum8(acc1);
    for jj in j..n {
        s += arow[jj] * wrow[jj];
    }
    s
}

/// Lane-tiled `out[m×k] = a[m×n] @ w[k×n]ᵀ`, row-parallel: both
/// operands are traversed contiguously (rows of `a` against rows of
/// `w`), so this is a pure streaming dot-product kernel.
pub fn matmul_a_wt_simd(
    a: &[f32],
    w: &[f32],
    out: &mut [f32],
    m: usize,
    n: usize,
    k: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    let bands = plan_bands(threads, m, n * k);
    for_each_row_band(out, m, k, bands, &|first, band| {
        for (bi, orow) in band.chunks_exact_mut(k).enumerate() {
            let arow = &a[(first + bi) * n..(first + bi + 1) * n];
            for (kk, o) in orow.iter_mut().enumerate() {
                *o = dot(arow, &w[kk * n..(kk + 1) * n], n);
            }
        }
    });
}
