//! Read-only checkpoint open for serving and `puffer ckpt info`:
//! rebuild the exact [`NativeBackend`] the trainer used from the
//! RunSpec embedded in a v2 checkpoint, and validate that the file's
//! parameter vector actually fits that architecture.
//!
//! This is the serve-side half of the contract
//! `train/checkpoint.rs` writes: training embeds the spec so inference
//! needs zero flags — the flat observation width, action head layout,
//! and recurrence all come out of the file.

use crate::backend::{NativeBackend, PolicyBackend};
use crate::policy::PolicySpec;
use crate::runspec::RunSpec;
use crate::train::Checkpoint;
use crate::wrappers::EnvSpec;
use anyhow::{Context, Result};

/// A checkpoint opened for inference: the rebuilt backend plus the
/// weights and metadata the server (or `ckpt info`) needs.
pub struct ServedModel {
    /// The embedded experiment spec, exactly as trained.
    pub spec: RunSpec,
    /// Backend rebuilt from the spec; its arch gives obs/action geometry.
    pub backend: NativeBackend,
    /// Flat parameter vector from the checkpoint file.
    pub params: Vec<f32>,
    /// Training step the checkpoint was written at.
    pub global_step: u64,
    /// Architecture key the checkpoint was saved under.
    pub spec_key: String,
    /// Checkpoint format version (2 = RunSpec-embedded).
    pub format_version: u32,
}

impl ServedModel {
    /// Open `path` read-only and rebuild its policy. Fails with an
    /// actionable message for v1 (spec-less) files, arch mismatches,
    /// and truncated parameter vectors.
    pub fn open(path: &str) -> Result<ServedModel> {
        let format_version = Checkpoint::probe_version(path)?;
        let ck = Checkpoint::load(path).context("loading checkpoint")?;
        let json = ck.run_spec_json.as_deref().with_context(|| {
            format!(
                "{path} is a v{format_version} checkpoint with no embedded RunSpec — \
                 serving and `ckpt info` need the v2 format, which records the \
                 experiment spec at save time. Re-train (or fine-tune via \
                 `puffer resume`) with this build to produce one"
            )
        })?;
        let spec = RunSpec::from_json_str(json)
            .with_context(|| format!("parsing the RunSpec embedded in {path}"))?;
        let backend = Self::backend_for(&spec)?;
        Self::check_fit(&backend, &ck, path)?;
        Ok(ServedModel {
            spec,
            backend,
            params: ck.params,
            global_step: ck.global_step,
            spec_key: ck.spec_key,
            format_version,
        })
    }

    /// Rebuild the native backend a spec trains with — the same
    /// construction path as `Trainer::from_run_spec`, minus the
    /// vectorizer and optimizer. Public so tests and the selftest can
    /// synthesize servable checkpoints without a training run.
    pub fn backend_for(spec: &RunSpec) -> Result<NativeBackend> {
        let tc = spec.train_config();
        let env_spec = EnvSpec::new(tc.env.as_str()).with_wrappers(tc.wrappers.iter().cloned());
        let probe = env_spec.build(0);
        let policy = tc
            .policy
            .clone()
            .unwrap_or_else(|| PolicySpec::default_for(&tc.env));
        let mut backend =
            NativeBackend::for_env_with_policy(&env_spec.key(), probe.as_ref(), &policy)?;
        backend.set_kernel_path(tc.kernels);
        Ok(backend)
    }

    /// Validate that a (re-)loaded checkpoint matches this model's
    /// architecture — shared by `open` and the hot-swap watcher, so a
    /// half-written or wrong-run file can never be published.
    pub fn check_compatible(&self, ck: &Checkpoint, path: &str) -> Result<()> {
        anyhow::ensure!(
            ck.spec_key == self.spec_key,
            "{path} was saved under arch key '{}' but this server loaded '{}' — \
             refusing to hot-swap weights across architectures",
            ck.spec_key,
            self.spec_key
        );
        anyhow::ensure!(
            ck.params.len() == self.params.len(),
            "{path} holds {} parameters, expected {}",
            ck.params.len(),
            self.params.len()
        );
        Ok(())
    }

    fn check_fit(backend: &NativeBackend, ck: &Checkpoint, path: &str) -> Result<()> {
        anyhow::ensure!(
            backend.key() == ck.spec_key,
            "{path} was saved under arch key '{}', but its embedded RunSpec \
             rebuilds '{}' — the checkpoint is internally inconsistent",
            ck.spec_key,
            backend.key()
        );
        anyhow::ensure!(
            ck.params.len() == backend.spec().n_params,
            "{path} holds {} parameters, but the rebuilt architecture needs {}",
            ck.params.len(),
            backend.spec().n_params
        );
        Ok(())
    }

    /// Flat observation row width clients must send.
    pub fn obs_dim(&self) -> usize {
        self.backend.arch().obs_dim
    }

    /// MultiDiscrete action slots per reply.
    pub fn slots(&self) -> usize {
        self.backend.arch().act_dims.len()
    }

    /// Per-slot action cardinalities.
    pub fn act_dims(&self) -> &[usize] {
        &self.backend.arch().act_dims
    }

    /// Recurrent state width per session (0 for feedforward policies).
    pub fn state_dim(&self) -> usize {
        self.backend.arch().state_dim()
    }

    /// Whether the policy carries LSTM state between steps.
    pub fn recurrent(&self) -> bool {
        self.backend.arch().is_recurrent()
    }
}
