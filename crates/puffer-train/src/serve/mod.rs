//! `puffer serve` — the dynamic-batching policy inference server: the
//! production traffic path that turns a trained checkpoint into a
//! network service (ROADMAP north-star item 2, the "millions of users"
//! half of the paper's play-nice pitch).
//!
//! ## Architecture
//!
//! - [`model`] opens a v2 (RunSpec-embedded) checkpoint read-only and
//!   rebuilds the exact [`NativeBackend`](crate::backend::NativeBackend)
//!   the trainer used — flat obs row width, action head, and recurrence
//!   are all known from the embedded spec, so clients send bare
//!   `obs_dim × f32` rows.
//! - [`server`] accepts concurrent localhost TCP connections speaking
//!   the length-prefixed binary protocol (or the newline-JSON debug
//!   mode — [`protocol`]) and routes each request to a batcher shard by
//!   session id.
//! - [`batcher`] coalesces queued requests into batched forward passes
//!   under a dual budget — `max_batch` rows or `max_wait_us` elapsed,
//!   whichever comes first. The request queue rides the loom-able
//!   [`crate::sync::queue`] facade; the close/drain protocol is model
//!   checked in `crates/puffer-train/tests/loom_models.rs`.
//! - [`session`] owns per-session LSTM h/c state for recurrent policies:
//!   created on first use, touched per request, reset on episode
//!   boundaries (the request's `reset` flag), evicted after
//!   `session_ttl_s` idle.
//! - Weight rollover reuses [`ParamSnapshot`](crate::policy::ParamSnapshot):
//!   a watcher thread re-reads the checkpoint path on change and
//!   publishes a new version; each shard acquires the latest snapshot
//!   between batches, so serving never blocks on a swap and every reply
//!   carries the monotone snapshot version it was computed with.
//! - [`selftest`] is the synthetic open-loop load generator behind
//!   `puffer serve --selftest` and `benches/serve_latency.rs`, reporting
//!   p50/p99 latency, batch occupancy, and sessions served into
//!   `BENCH_serve.json` via the `PUFFER_BENCH_JSON` hook.
//!
//! Inference is deterministic (greedy argmax per action slot), which is
//! what makes the batched-vs-serial bit-equality contract testable: the
//! native forward math is row-independent, so a request's reply is
//! bit-identical whether it rode a 64-row batch or a solo forward.

// Serving is plumbing over safe primitives; the unsafe surface stays in
// vector/ (CONCURRENCY.md).
#![forbid(unsafe_code)]

pub mod batcher;
pub mod model;
pub mod protocol;
pub mod selftest;
pub mod server;
pub mod session;

pub use model::ServedModel;
pub use protocol::{StepReply, StepRequest};
pub use server::{Server, ServerHandle};

use crate::sync::atomic::{AtomicU64, Ordering};

// The plain-data `[serve]` config lives in puffer-core (the spec layer
// needs it without linking this crate); re-exported here so
// `crate::serve::ServeConfig` keeps resolving.
pub use puffer_core::serve::ServeConfig;

/// Shared serving counters, updated by the batcher shards and read by
/// the CLI/selftest. All counters are independent tallies — no cross
/// counter invariant is read concurrently — so Relaxed is sufficient
/// throughout.
#[derive(Debug)]
pub struct ServeStats {
    /// Requests answered (replies handed to a connection writer).
    pub requests: AtomicU64,
    /// Forward passes executed.
    pub batches: AtomicU64,
    /// Total rows across all forward passes.
    pub rows: AtomicU64,
    /// Largest single-forward row count observed.
    pub max_batch: AtomicU64,
    /// Forward passes with more than one row — the coalescing proof the
    /// smoke test asserts on.
    pub multi_row_batches: AtomicU64,
    /// Sessions created across all shards.
    pub sessions: AtomicU64,
    /// Sessions evicted by the idle TTL.
    pub evicted: AtomicU64,
    /// Replies dropped because the client hung up before the answer.
    pub hangups: AtomicU64,
}

impl Default for ServeStats {
    // Hand-written (not derived) so it builds against both std and loom
    // atomics without relying on loom's trait surface.
    fn default() -> Self {
        ServeStats {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
            multi_row_batches: AtomicU64::new(0),
            sessions: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            hangups: AtomicU64::new(0),
        }
    }
}

impl ServeStats {
    /// Raise `max_batch` to at least `rows`. CAS loop because the sync
    /// facade's loom doubles don't provide `fetch_max`.
    pub fn note_batch_size(&self, rows: u64) {
        // ordering: Relaxed — a monotone stat gauge; no other memory is
        // published through it.
        let mut cur = self.max_batch.load(Ordering::Relaxed);
        while rows > cur {
            // ordering: Relaxed — same gauge, success and failure alike.
            match self
                .max_batch
                .compare_exchange_weak(cur, rows, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Mean rows per forward pass.
    pub fn occupancy(&self) -> f64 {
        // ordering: Relaxed — independent counters, no paired edge.
        let batches = self.batches.load(Ordering::Relaxed);
        if batches == 0 {
            return 0.0;
        }
        // ordering: Relaxed — as above.
        self.rows.load(Ordering::Relaxed) as f64 / batches as f64
    }
}
