//! Synthetic open-loop load generator: `puffer serve --selftest` and
//! `benches/serve_latency.rs` drive a real in-process server over real
//! TCP sockets with deterministic traffic, then report latency
//! percentiles, batch occupancy, and sessions served — into
//! `BENCH_serve.json` when `PUFFER_BENCH_JSON` is set.
//!
//! The run doubles as an end-to-end correctness gate: every request
//! must be answered (zero drops), per-session snapshot versions must be
//! monotone, and a mid-run checkpoint rewrite must roll the weights
//! live (the watcher picks it up while traffic flows).

use super::model::ServedModel;
use super::protocol::{self, StepRequest};
use super::server::Server;
use super::ServeConfig;
use crate::runspec::RunSpec;
use crate::sync::atomic::Ordering;
use crate::train::Checkpoint;
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Load-generator shape. Defaults match the acceptance gate: ≥10k
/// requests over ≥64 sessions.
#[derive(Clone, Debug)]
pub struct SelftestConfig {
    /// Total requests across all clients.
    pub requests: usize,
    /// Concurrent sessions (partitioned evenly across clients).
    pub sessions: usize,
    /// Client connections.
    pub clients: usize,
    /// Pipelining window per client: requests in flight before the
    /// client reads a reply. >1 is what gives the batcher something to
    /// coalesce.
    pub window: usize,
    /// Rewrite the checkpoint mid-run to exercise the hot-swap watcher.
    pub hot_swap: bool,
}

impl Default for SelftestConfig {
    fn default() -> Self {
        SelftestConfig {
            requests: 10_000,
            sessions: 64,
            clients: 8,
            window: 8,
            hot_swap: true,
        }
    }
}

/// What the run measured. All latencies in microseconds, wall-clock
/// from request write to reply read on the client thread.
#[derive(Clone, Debug)]
pub struct SelftestReport {
    pub requests: u64,
    pub sessions: u64,
    pub batches: u64,
    pub occupancy: f64,
    pub max_batch: u64,
    pub multi_row_batches: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub dropped: u64,
    pub evicted: u64,
    /// Highest weight-snapshot version observed in replies (≥1 proves
    /// the hot-swap landed).
    pub max_version: u64,
    pub elapsed_ms: u64,
}

/// Write a freshly initialized (untrained) checkpoint for `spec` —
/// tests and the latency bench use this to get a servable file without
/// running a training loop.
pub fn write_synthetic_checkpoint(spec: &RunSpec, path: &str) -> Result<()> {
    use crate::backend::PolicyBackend;
    let mut backend = ServedModel::backend_for(spec)?;
    let params = backend.init_params()?;
    let n = params.len();
    Checkpoint {
        spec_key: backend.key().to_string(),
        run_spec_json: Some(spec.to_json().dump()),
        global_step: 0,
        params,
        adam_m: vec![0.0; n],
        adam_v: vec![0.0; n],
        adam_step: 0.0,
    }
    .save(path)
}

/// Deterministic observation for `(session, step)` — cheap, spread over
/// [0, 1), and unique enough that replies can be sanity-checked against
/// a serial forward in tests.
pub fn synthetic_obs(session: u64, step: u64, obs_dim: usize) -> Vec<f32> {
    (0..obs_dim)
        .map(|j| {
            let x = session
                .wrapping_mul(31)
                .wrapping_add(step.wrapping_mul(7))
                .wrapping_add(j as u64)
                % 97;
            x as f32 / 97.0
        })
        .collect()
}

/// Run the load against `ckpt_path`. Binds an ephemeral port (the
/// `cfg.port` value is ignored by design — a selftest never squats the
/// configured one).
pub fn run(ckpt_path: &str, cfg: &ServeConfig, st: &SelftestConfig) -> Result<SelftestReport> {
    anyhow::ensure!(st.clients >= 1, "selftest needs at least one client");
    anyhow::ensure!(
        st.sessions >= st.clients,
        "selftest needs at least one session per client ({} sessions, {} clients)",
        st.sessions,
        st.clients
    );
    let model = ServedModel::open(ckpt_path)?;
    let obs_dim = model.obs_dim();
    let mut serve_cfg = cfg.clone();
    serve_cfg.port = 0;
    let handle = Server::start(model, &serve_cfg, Some(ckpt_path))?;
    let addr = handle.addr();

    let started = Instant::now();
    let per_client = st.requests / st.clients;
    let sessions_per_client = st.sessions / st.clients;
    let mut clients = Vec::with_capacity(st.clients);
    for client_idx in 0..st.clients {
        let window = st.window.max(1);
        clients.push(std::thread::spawn(move || -> Result<(Vec<u64>, u64)> {
            let stream = TcpStream::connect(addr).context("selftest connect")?;
            stream.set_nodelay(true).ok();
            let mut writer = BufWriter::new(stream.try_clone().context("clone stream")?);
            let mut reader = BufReader::new(stream);
            writer.write_all(protocol::CLIENT_MAGIC).context("magic")?;
            writer.flush().context("magic flush")?;
            let (dim, slots) = protocol::read_hello(&mut reader)?;
            anyhow::ensure!(dim == obs_dim, "hello obs_dim {dim} != model {obs_dim}");

            let session_of = |k: usize| -> u64 {
                (client_idx * sessions_per_client + k % sessions_per_client) as u64
            };
            let mut steps: HashMap<u64, u64> = HashMap::new();
            let mut sent_at: HashMap<u64, VecDeque<Instant>> = HashMap::new();
            let mut last_version: HashMap<u64, u64> = HashMap::new();
            let mut latencies = Vec::with_capacity(per_client);
            let mut max_version = 0u64;
            let (mut sent, mut received) = (0usize, 0usize);
            while received < per_client {
                while sent < per_client && sent - received < window {
                    let session = session_of(sent);
                    let step = steps.entry(session).or_insert(0);
                    let req = StepRequest {
                        session,
                        // Periodic episode boundary: exercises per-row reset.
                        reset: *step % 16 == 0,
                        obs: synthetic_obs(session, *step, obs_dim),
                    };
                    *step += 1;
                    sent_at.entry(session).or_default().push_back(Instant::now());
                    protocol::write_request(&mut writer, &req)?;
                    writer.flush().context("request flush")?;
                    sent += 1;
                }
                let rep = protocol::read_reply(&mut reader, slots)?
                    .context("server closed before all replies arrived")?;
                let t0 = sent_at
                    .get_mut(&rep.session)
                    .and_then(VecDeque::pop_front)
                    .context("reply for a session with nothing outstanding")?;
                latencies.push(t0.elapsed().as_micros() as u64);
                let prev = last_version.entry(rep.session).or_insert(0);
                anyhow::ensure!(
                    rep.version >= *prev,
                    "session {} saw version {} after {} — snapshot versions regressed",
                    rep.session,
                    rep.version,
                    *prev
                );
                *prev = rep.version;
                max_version = max_version.max(rep.version);
                received += 1;
            }
            Ok((latencies, max_version))
        }));
    }

    // Hot-swap mid-run: once a quarter of the traffic has been served,
    // rewrite the checkpoint in place (same weights, bumped step) and
    // wait for the watcher to publish it while the clients keep going.
    let mut swap_error = None;
    if st.hot_swap {
        let quarter = (st.requests / 4) as u64;
        let swap_deadline = Instant::now() + Duration::from_secs(30);
        // ordering: Relaxed — stat counter poll, no data dependence.
        while handle.stats().requests.load(Ordering::Relaxed) < quarter {
            if Instant::now() > swap_deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        match Checkpoint::load(ckpt_path) {
            Ok(mut ck) => {
                ck.global_step += 1;
                if let Err(e) = ck.save(ckpt_path) {
                    swap_error = Some(e);
                } else {
                    let publish_deadline = Instant::now() + Duration::from_secs(10);
                    while handle.snapshot_version() == 0 && Instant::now() < publish_deadline {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    if handle.snapshot_version() == 0 {
                        swap_error =
                            Some(anyhow::anyhow!("watcher never published the rewritten file"));
                    }
                }
            }
            Err(e) => swap_error = Some(e),
        }
    }

    let mut latencies = Vec::with_capacity(st.requests);
    let mut max_version = 0u64;
    for c in clients {
        // PANIC: client threads hold no shared lock; propagate panics.
        let (lat, v) = c.join().expect("selftest client panicked")?;
        latencies.extend(lat);
        max_version = max_version.max(v);
    }
    if let Some(e) = swap_error {
        return Err(e.context("hot-swap leg of the selftest"));
    }

    let stats = handle.stats();
    latencies.sort_unstable();
    let pct = |p: f64| -> u64 {
        if latencies.is_empty() {
            return 0;
        }
        let idx = ((latencies.len() as f64 - 1.0) * p).round() as usize;
        latencies[idx]
    };
    // ordering: Relaxed — every client joined; these are final tallies.
    let answered = stats.requests.load(Ordering::Relaxed);
    let report = SelftestReport {
        requests: answered,
        sessions: stats.sessions.load(Ordering::Relaxed),
        batches: stats.batches.load(Ordering::Relaxed),
        occupancy: stats.occupancy(),
        max_batch: stats.max_batch.load(Ordering::Relaxed),
        multi_row_batches: stats.multi_row_batches.load(Ordering::Relaxed),
        p50_us: pct(0.50),
        p99_us: pct(0.99),
        dropped: answered.saturating_sub(latencies.len() as u64)
            + stats.hangups.load(Ordering::Relaxed),
        evicted: stats.evicted.load(Ordering::Relaxed),
        max_version,
        elapsed_ms: started.elapsed().as_millis() as u64,
    };
    handle.shutdown()?;
    Ok(report)
}

/// The report as the `BENCH_serve.json` object.
pub fn report_json(r: &SelftestReport) -> Json {
    json::obj(vec![
        ("bench", json::s("serve_latency")),
        ("requests", json::num(r.requests as f64)),
        ("sessions", json::num(r.sessions as f64)),
        ("batches", json::num(r.batches as f64)),
        ("occupancy", json::num(r.occupancy)),
        ("max_batch", json::num(r.max_batch as f64)),
        ("multi_row_batches", json::num(r.multi_row_batches as f64)),
        ("p50_us", json::num(r.p50_us as f64)),
        ("p99_us", json::num(r.p99_us as f64)),
        ("dropped", json::num(r.dropped as f64)),
        ("evicted", json::num(r.evicted as f64)),
        ("max_version", json::num(r.max_version as f64)),
        ("elapsed_ms", json::num(r.elapsed_ms as f64)),
    ])
}

/// Honor `PUFFER_BENCH_JSON`: write the report there if set, returning
/// the path written.
pub fn maybe_write_bench_json(r: &SelftestReport) -> Result<Option<String>> {
    let Ok(path) = std::env::var("PUFFER_BENCH_JSON") else {
        return Ok(None);
    };
    std::fs::write(&path, report_json(r).dump())
        .with_context(|| format!("writing {path}"))?;
    Ok(Some(path))
}

/// Human-readable summary for the CLI.
pub fn print_report(r: &SelftestReport) {
    println!(
        "serve selftest: {} requests over {} sessions in {} ms",
        r.requests, r.sessions, r.elapsed_ms
    );
    println!(
        "  batches {}  occupancy {:.2}  max batch {}  multi-row {}",
        r.batches, r.occupancy, r.max_batch, r.multi_row_batches
    );
    println!(
        "  latency p50 {} us  p99 {} us  dropped {}  weight version {}",
        r.p50_us, r.p99_us, r.dropped, r.max_version
    );
}
