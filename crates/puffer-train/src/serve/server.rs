//! The TCP front end: accept localhost connections, decode frames,
//! route requests to batcher shards, stream replies back, and watch the
//! checkpoint file for weight rollovers.
//!
//! Thread shape (for `threads = N` shards):
//!
//! ```text
//! accept loop ──spawns──▶ per-connection reader ──Job──▶ shard 0..N
//!                         per-connection writer ◀─reply── (queue)
//! watcher ──publish──▶ ParamSnapshot ◀─acquire── shards
//! ```
//!
//! Close/drain: connection readers drop their shard senders at client
//! EOF; [`ServerHandle::shutdown`] stops the accept loop and drops its
//! senders too, so each shard's queue reports disconnected exactly when
//! no request can arrive anymore — the drain guarantee modeled in
//! `crates/puffer-train/tests/loom_models.rs`.

use super::batcher::{Job, Shard};
use super::model::ServedModel;
use super::protocol::{self, StepReply};
use super::{ServeConfig, ServeStats};
use crate::policy::ParamSnapshot;
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::queue::{self, Sender};
use crate::sync::{lock_unpoisoned, Arc, Mutex};
use crate::train::Checkpoint;
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::thread::JoinHandle;
use std::time::{Duration, SystemTime};

/// Marker type for the running server (constructed via
/// [`Server::start`], controlled through [`ServerHandle`]).
pub struct Server;

/// A running inference server. Dropping the handle without calling
/// [`shutdown`](Self::shutdown) leaves the threads serving until
/// process exit — call `shutdown` for a clean drain.
pub struct ServerHandle {
    addr: SocketAddr,
    stats: Arc<ServeStats>,
    snapshot: Arc<ParamSnapshot>,
    n_params: usize,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    watcher: Option<JoinHandle<()>>,
    shards: Vec<JoinHandle<Result<()>>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind, spawn the shard/accept/watcher threads, and return the
    /// control handle. `cfg.port == 0` binds an ephemeral port — read
    /// it back from [`ServerHandle::addr`].
    pub fn start(model: ServedModel, cfg: &ServeConfig, watch_path: Option<&str>) -> Result<ServerHandle> {
        anyhow::ensure!(cfg.threads >= 1, "serve.threads must be >= 1");
        anyhow::ensure!(cfg.max_batch >= 1, "serve.max_batch must be >= 1");
        let listener = TcpListener::bind(("127.0.0.1", cfg.port))
            .with_context(|| format!("binding 127.0.0.1:{}", cfg.port))?;
        let addr = listener.local_addr().context("reading bound address")?;

        let stats = Arc::new(ServeStats::default());
        let snapshot = Arc::new(ParamSnapshot::new(model.params.clone()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let mut shard_txs = Vec::with_capacity(cfg.threads);
        let mut shards = Vec::with_capacity(cfg.threads);
        for _ in 0..cfg.threads {
            let (tx, rx) = queue::channel::<Job>(None);
            let shard = Shard::new(model.backend.clone(), cfg, snapshot.clone(), stats.clone());
            shards.push(std::thread::spawn(move || shard.run(rx)));
            shard_txs.push(tx);
        }

        let watcher = watch_path.map(|path| {
            spawn_watcher(
                path.to_string(),
                model.spec_key.clone(),
                model.params.len(),
                snapshot.clone(),
                shutdown.clone(),
            )
        });

        let geometry = ConnGeometry {
            obs_dim: model.obs_dim(),
            slots: model.slots(),
            threads: cfg.threads,
        };
        let accept = {
            let (shutdown, conns, stats) = (shutdown.clone(), conns.clone(), stats.clone());
            std::thread::spawn(move || {
                for stream in listener.incoming() {
                    // ordering: Relaxed — the dummy wake-up connection from
                    // shutdown() orders itself; the flag is just a latch.
                    if shutdown.load(Ordering::Relaxed) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        Err(e) => {
                            eprintln!("serve: accept failed: {e}");
                            continue;
                        }
                    };
                    let (txs, stats) = (shard_txs.clone(), stats.clone());
                    let handle = std::thread::spawn(move || {
                        if let Err(e) = handle_connection(stream, geometry, &txs, &stats) {
                            eprintln!("serve: connection error: {e:#}");
                        }
                    });
                    lock_unpoisoned(&conns).push(handle);
                }
                // Dropping shard_txs here (with every connection reader
                // already tracked) lets the shards drain and exit.
            })
        };

        Ok(ServerHandle {
            addr,
            stats,
            snapshot,
            n_params: model.params.len(),
            shutdown,
            accept: Some(accept),
            watcher,
            shards,
            conns,
        })
    }
}

impl ServerHandle {
    /// The bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Current weight-snapshot version (0 = as loaded).
    pub fn snapshot_version(&self) -> u64 {
        self.snapshot.version()
    }

    /// Publish new weights directly (the in-process twin of the file
    /// watcher — tests use it for deterministic hot-swaps).
    pub fn publish_params(&self, params: &[f32]) -> Result<u64> {
        anyhow::ensure!(
            params.len() == self.n_params,
            "published weights have {} parameters, expected {}",
            params.len(),
            self.n_params
        );
        Ok(self.snapshot.publish(params))
    }

    /// Stop accepting, drain in-flight requests, and join every thread.
    /// Connections must be closed by their clients first (this is a
    /// localhost tool; readers block on their sockets).
    pub fn shutdown(mut self) -> Result<()> {
        // ordering: Relaxed — the accept loop re-checks after its next
        // (dummy) connection; no data is published through this flag.
        self.shutdown.store(true, Ordering::Relaxed);
        // Wake the accept loop so it observes the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            // PANIC: propagating a panic from the accept thread — it holds
            // no lock anyone else needs by this point.
            h.join().expect("accept thread panicked");
        }
        for h in lock_unpoisoned(&self.conns).drain(..) {
            // PANIC: as above, for connection threads.
            h.join().expect("connection thread panicked");
        }
        for h in self.shards.drain(..) {
            // PANIC: as above, for shard threads.
            h.join().expect("shard thread panicked")?;
        }
        if let Some(h) = self.watcher.take() {
            // PANIC: as above, for the watcher thread.
            h.join().expect("watcher thread panicked");
        }
        Ok(())
    }
}

#[derive(Clone, Copy)]
struct ConnGeometry {
    obs_dim: usize,
    slots: usize,
    threads: usize,
}

/// Serve one client connection until EOF. The reader (this thread)
/// decodes frames and routes them to shards; a paired writer thread
/// streams replies back so a slow batch never blocks decode.
fn handle_connection(
    stream: TcpStream,
    geo: ConnGeometry,
    shard_txs: &[Sender<Job>],
    stats: &Arc<ServeStats>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut write_stream = stream.try_clone().context("cloning connection stream")?;
    let mut reader = BufReader::new(stream);

    // Mode detection: binary clients lead with `PUFB`, debug clients
    // with a `{`.
    let mut first = [0u8; 1];
    if !read_one(&mut reader, &mut first)? {
        return Ok(()); // connected and left (the shutdown wake-up does this)
    }
    let binary = first[0] != b'{';

    // The hello goes out before the writer thread exists, so it cannot
    // race a reply: no request has been routed yet.
    if binary {
        let mut rest = [0u8; 3];
        anyhow::ensure!(read_one(&mut reader, &mut rest)?, "client closed mid-magic");
        let magic = [first[0], rest[0], rest[1], rest[2]];
        anyhow::ensure!(
            &magic == protocol::CLIENT_MAGIC,
            "bad client magic {magic:?} — expected {:?} or a JSON line",
            protocol::CLIENT_MAGIC
        );
        protocol::write_hello(&mut write_stream, geo.obs_dim, geo.slots)?;
    } else {
        writeln!(write_stream, "{}", protocol::hello_json(geo.obs_dim, geo.slots))
            .context("serve hello write")?;
    }

    let (reply_tx, reply_rx) = queue::channel::<StepReply>(None);
    let writer = std::thread::spawn(move || {
        let mut w = BufWriter::new(write_stream);
        while let Some(rep) = reply_rx.recv() {
            let res = if binary {
                protocol::write_reply(&mut w, &rep)
            } else {
                writeln!(w, "{}", protocol::reply_to_json(&rep)).map_err(anyhow::Error::from)
            };
            if res.and_then(|_| w.flush().map_err(anyhow::Error::from)).is_err() {
                // Client went away; drain remaining replies quietly so
                // the shards never block on this connection.
                while reply_rx.recv().is_some() {}
                return;
            }
        }
    });

    let route = |req: super::protocol::StepRequest| {
        let shard = (req.session % geo.threads as u64) as usize;
        let job = Job { req, reply: reply_tx.clone() };
        if shard_txs[shard].send(job).is_err() {
            // Server shutting down mid-connection: count it like a hangup.
            // ordering: Relaxed — independent stat counter.
            stats.hangups.fetch_add(1, Ordering::Relaxed);
        }
    };

    let read_result = if binary {
        loop {
            match protocol::read_request(&mut reader, geo.obs_dim) {
                Ok(Some(req)) => route(req),
                Ok(None) => break Ok(()),
                Err(e) => break Err(e),
            }
        }
    } else {
        let mut line = vec![first[0]];
        loop {
            match read_line(&mut reader, &mut line)? {
                None => break Ok(()),
                Some(text) => {
                    let req = protocol::request_from_json(text, geo.obs_dim)?;
                    route(req);
                }
            }
            line.clear();
        }
    };

    // Reader done: drop our reply sender so the writer exits once every
    // in-flight job's clone is consumed.
    drop(reply_tx);
    // PANIC: writer thread holds no shared lock; propagate its panics.
    writer.join().expect("connection writer panicked");
    read_result
}

/// Fill `buf` exactly; `Ok(false)` if EOF arrived first.
fn read_one(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        let n = r.read(&mut buf[got..]).context("serve socket read")?;
        if n == 0 {
            return Ok(false);
        }
        got += n;
    }
    Ok(true)
}

/// Read one newline-terminated line into `buf` (which may already hold
/// the first byte). `None` at EOF with nothing buffered.
fn read_line<'a>(r: &mut impl Read, buf: &'a mut Vec<u8>) -> Result<Option<&'a str>> {
    let mut byte = [0u8; 1];
    loop {
        let n = r.read(&mut byte).context("serve socket read")?;
        if n == 0 {
            if buf.iter().all(|b| b.is_ascii_whitespace()) {
                return Ok(None);
            }
            anyhow::bail!("connection closed mid-line");
        }
        if byte[0] == b'\n' {
            let text = std::str::from_utf8(buf).context("request line is not UTF-8")?;
            return Ok(Some(text));
        }
        buf.push(byte[0]);
    }
}

fn file_stamp(path: &str) -> Option<(SystemTime, u64)> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.modified().ok()?, meta.len()))
}

/// Poll the checkpoint path and publish new weights when it changes.
/// Validation failures keep the previous weights — a half-written or
/// incompatible file can never reach the batcher.
fn spawn_watcher(
    path: String,
    spec_key: String,
    n_params: usize,
    snapshot: Arc<ParamSnapshot>,
    shutdown: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let mut last = file_stamp(&path);
        // ordering: Relaxed — shutdown latch only, no data published.
        while !shutdown.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(50));
            let cur = file_stamp(&path);
            if cur.is_none() || cur == last {
                continue;
            }
            // One load attempt per observed stamp: a partial write fails
            // validation, keeps the old weights, and the completed write
            // changes the stamp again.
            last = cur;
            match Checkpoint::load(&path) {
                Ok(ck) if ck.spec_key != spec_key => {
                    eprintln!(
                        "serve: ignoring {path}: arch key '{}' does not match served '{spec_key}'",
                        ck.spec_key
                    );
                }
                Ok(ck) if ck.params.len() != n_params => {
                    eprintln!(
                        "serve: ignoring {path}: {} parameters, expected {n_params}",
                        ck.params.len()
                    );
                }
                Ok(ck) => {
                    let v = snapshot.publish(&ck.params);
                    eprintln!(
                        "serve: weights rolled to version {v} (step {})",
                        ck.global_step
                    );
                }
                Err(e) => {
                    eprintln!("serve: ignoring unreadable {path}: {e:#}");
                }
            }
        }
    })
}
