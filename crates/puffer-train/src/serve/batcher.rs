//! The dynamic batcher: drain queued requests into one forward pass
//! under a dual budget — `max_batch` rows, or `max_wait_us` elapsed,
//! whichever trips first.
//!
//! Each shard owns a request queue ([`crate::sync::queue`]), a clone of
//! the [`NativeBackend`], and a [`SessionTable`]; sessions are pinned
//! to shards by id, so per-session request order — and therefore the
//! recurrent-state trajectory — is exactly what a serial client would
//! produce. Weights are [`ParamSnapshot::acquire`]d once per collected
//! batch, never mid-batch, so a hot-swap lands between forwards.
//!
//! [`collect_batch`] is deliberately time-free: the deadline is an
//! injected `expired()` closure, so the production shard passes an
//! `Instant` budget while the loom model in `crates/puffer-train/tests/loom_models.rs`
//! passes a bounded counter and model-checks the close/drain protocol
//! (no request is ever stranded when the queue closes).

use super::protocol::{StepReply, StepRequest};
use super::session::SessionTable;
use super::{ServeConfig, ServeStats};
use crate::backend::{NativeBackend, PolicyBackend};
use crate::policy::{greedy_actions, ParamSnapshot};
use crate::sync::atomic::Ordering;
use crate::sync::queue::{Receiver, Sender, TryRecv};
use crate::sync::{yield_now, Arc};
use anyhow::Result;
use std::collections::HashSet;

/// One queued request plus the way home: a clone of its connection's
/// reply sender. A send error (client hung up) is counted, not fatal.
pub struct Job {
    pub req: StepRequest,
    pub reply: Sender<StepReply>,
}

/// Drain up to `max_batch` items from `rx`: block for the first item,
/// then poll without blocking until the batch fills, the queue
/// momentarily empties *and* `expired()` says the time budget is spent,
/// or every sender hangs up. `None` means the queue is closed and
/// drained — the shard's exit signal.
///
/// `expired` is only consulted while the queue is empty, so a saturated
/// queue always fills the batch, and the first call happens right after
/// the first item — callers start their clock lazily inside the
/// closure.
pub fn collect_batch<T>(
    rx: &Receiver<T>,
    max_batch: usize,
    mut expired: impl FnMut() -> bool,
) -> Option<Vec<T>> {
    let first = rx.recv()?;
    let mut batch = Vec::with_capacity(max_batch.min(64));
    batch.push(first);
    while batch.len() < max_batch {
        match rx.try_recv() {
            TryRecv::Item(item) => batch.push(item),
            TryRecv::Disconnected => break,
            TryRecv::Empty => {
                if expired() {
                    break;
                }
                yield_now();
            }
        }
    }
    Some(batch)
}

/// Split a batch so no session appears twice within one forward: a
/// repeated session must see the state its previous request wrote.
/// Splitting at the first repeat preserves arrival (and therefore
/// per-session) order.
fn split_unique_sessions(jobs: Vec<Job>) -> Vec<Vec<Job>> {
    let mut out = Vec::new();
    let mut seen = HashSet::new();
    let mut cur: Vec<Job> = Vec::new();
    for j in jobs {
        if !seen.insert(j.req.session) {
            out.push(std::mem::take(&mut cur));
            seen.clear();
            seen.insert(j.req.session);
        }
        cur.push(j);
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// One batcher shard: loop collect → forward → reply until the request
/// queue closes. Owns its backend clone and session table outright.
pub struct Shard {
    backend: NativeBackend,
    sessions: SessionTable,
    snapshot: Arc<ParamSnapshot>,
    stats: Arc<ServeStats>,
    max_batch: usize,
    max_wait: std::time::Duration,
    obs_dim: usize,
    act_dims: Vec<usize>,
    recurrent: bool,
    // Per-shard forward scratch: gather buffers + output activations,
    // reused every batch through the backend's `*_into` kernel entry
    // points so the steady-state hot path allocates nothing.
    obs_buf: Vec<f32>,
    h_buf: Vec<f32>,
    c_buf: Vec<f32>,
    out_ff: crate::backend::Forward,
    out_lstm: crate::backend::ForwardLstm,
}

impl Shard {
    pub fn new(
        backend: NativeBackend,
        cfg: &ServeConfig,
        snapshot: Arc<ParamSnapshot>,
        stats: Arc<ServeStats>,
    ) -> Self {
        let arch = backend.arch();
        let (state_dim, obs_dim) = (arch.state_dim(), arch.obs_dim);
        let (act_dims, recurrent) = (arch.act_dims.clone(), arch.is_recurrent());
        Shard {
            sessions: SessionTable::new(
                state_dim,
                std::time::Duration::from_secs(cfg.session_ttl_s),
            ),
            snapshot,
            stats,
            max_batch: cfg.max_batch,
            max_wait: std::time::Duration::from_micros(cfg.max_wait_us),
            obs_dim,
            act_dims,
            recurrent,
            backend,
            obs_buf: Vec::new(),
            h_buf: Vec::new(),
            c_buf: Vec::new(),
            out_ff: crate::backend::Forward::default(),
            out_lstm: crate::backend::ForwardLstm::default(),
        }
    }

    /// Run until the queue closes (server shutdown: the accept loop and
    /// every connection reader drop their senders). Every request
    /// received before close gets a reply — the drain guarantee the
    /// loom model checks on [`collect_batch`].
    pub fn run(mut self, rx: Receiver<Job>) -> Result<()> {
        let max_wait = self.max_wait;
        loop {
            let mut deadline = None;
            let expired = move || {
                let d = *deadline.get_or_insert_with(|| std::time::Instant::now() + max_wait);
                std::time::Instant::now() >= d
            };
            let Some(jobs) = collect_batch(&rx, self.max_batch, expired) else {
                return Ok(());
            };
            // Acquire once per collected batch: every row of a batch is
            // answered by one consistent weight version.
            let (version, params) = self.snapshot.acquire();
            let groups = if self.recurrent {
                split_unique_sessions(jobs)
            } else {
                vec![jobs]
            };
            for group in groups {
                self.forward_group(group, version, &params)?;
            }
            let evicted = self.sessions.evict_idle(false);
            if evicted > 0 {
                // ordering: Relaxed — independent stat counter.
                self.stats.evicted.fetch_add(evicted as u64, Ordering::Relaxed);
            }
        }
    }

    fn forward_group(&mut self, group: Vec<Job>, version: u64, params: &[f32]) -> Result<()> {
        let rows = group.len();
        // Gather into the shard's reusable buffers (cleared, capacity
        // kept) and run the allocation-free `*_into` forward.
        self.obs_buf.clear();
        self.h_buf.clear();
        self.c_buf.clear();
        let created_before = self.sessions.created();
        for job in &group {
            anyhow::ensure!(
                job.req.obs.len() == self.obs_dim,
                "request for session {} carries {} obs values, expected {}",
                job.req.session,
                job.req.obs.len(),
                self.obs_dim
            );
            self.obs_buf.extend_from_slice(&job.req.obs);
            // Creates/touches the session either way; gathers zero-width
            // state for feedforward policies.
            self.sessions
                .gather(job.req.session, job.req.reset, &mut self.h_buf, &mut self.c_buf);
        }
        let (logits, values): (&[f32], &[f32]) = if self.recurrent {
            self.backend.forward_lstm_into(
                params,
                &self.obs_buf,
                &self.h_buf,
                &self.c_buf,
                rows,
                &mut self.out_lstm,
            )?;
            let out = &self.out_lstm;
            let sd = out.h.len() / rows;
            for (i, job) in group.iter().enumerate() {
                self.sessions.scatter(
                    job.req.session,
                    &out.h[i * sd..(i + 1) * sd],
                    &out.c[i * sd..(i + 1) * sd],
                );
            }
            (&out.logits, &out.values)
        } else {
            self.backend
                .forward_into(params, &self.obs_buf, rows, &mut self.out_ff)?;
            (&self.out_ff.logits, &self.out_ff.values)
        };
        let slot_sum: usize = self.act_dims.iter().sum();
        for (i, job) in group.into_iter().enumerate() {
            let row = &logits[i * slot_sum..(i + 1) * slot_sum];
            let reply = StepReply {
                session: job.req.session,
                version,
                value: values[i],
                actions: greedy_actions(row, &self.act_dims),
            };
            if job.reply.send(reply).is_err() {
                // ordering: Relaxed — independent stat counter.
                self.stats.hangups.fetch_add(1, Ordering::Relaxed);
            }
        }
        let created = self.sessions.created() - created_before;
        // ordering: Relaxed — independent stat counters throughout; the
        // selftest reads them after joining every thread.
        self.stats.sessions.fetch_add(created, Ordering::Relaxed);
        self.stats.requests.fetch_add(rows as u64, Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
        self.stats.rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.stats.note_batch_size(rows as u64);
        if rows > 1 {
            self.stats.multi_row_batches.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::queue;

    #[test]
    fn collect_blocks_for_the_first_item_then_fills() {
        let (tx, rx) = queue::channel::<u32>(None);
        for v in 0..5 {
            tx.send(v).unwrap();
        }
        let batch = collect_batch(&rx, 3, || false).unwrap();
        assert_eq!(batch, vec![0, 1, 2], "row budget caps the batch");
        let batch = collect_batch(&rx, 8, || true).unwrap();
        assert_eq!(batch, vec![3, 4], "queue drained + expired closes the batch");
    }

    #[test]
    fn collect_returns_none_on_a_closed_drained_queue() {
        let (tx, rx) = queue::channel::<u32>(None);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(collect_batch(&rx, 4, || false), Some(vec![1]));
        assert_eq!(collect_batch(&rx, 4, || false), None);
    }

    #[test]
    fn expired_is_not_consulted_while_items_flow() {
        let (tx, rx) = queue::channel::<u32>(None);
        for v in 0..4 {
            tx.send(v).unwrap();
        }
        // An instantly-expired budget still yields a full batch when the
        // queue never runs empty.
        let batch = collect_batch(&rx, 4, || true).unwrap();
        assert_eq!(batch.len(), 4);
    }

    #[test]
    fn repeated_sessions_split_into_ordered_groups() {
        let (reply_tx, _reply_rx) = queue::channel::<StepReply>(None);
        let job = |session: u64, tag: f32| Job {
            req: StepRequest { session, reset: false, obs: vec![tag] },
            reply: reply_tx.clone(),
        };
        let groups =
            split_unique_sessions(vec![job(1, 0.0), job(2, 1.0), job(1, 2.0), job(1, 3.0)]);
        let shape: Vec<Vec<(u64, f32)>> = groups
            .iter()
            .map(|g| g.iter().map(|j| (j.req.session, j.req.obs[0])).collect())
            .collect();
        assert_eq!(
            shape,
            vec![
                vec![(1, 0.0), (2, 1.0)],
                vec![(1, 2.0)],
                vec![(1, 3.0)],
            ],
            "session 1's requests stay in arrival order, one per group"
        );
    }
}
