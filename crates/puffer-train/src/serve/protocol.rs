//! The serve wire protocol: a length-prefixed binary framing for
//! production clients, plus a newline-JSON debug mode for poking the
//! server with `nc`. Both encode and decode live here so the server,
//! the selftest load generator, the latency bench, and the tests all
//! speak through one implementation.
//!
//! ## Binary mode
//!
//! All integers little-endian. The client opens with the 4-byte magic
//! `PUFB`; the server answers with a hello:
//!
//! ```text
//! hello  := "PUFS" u32 obs_dim u32 slots
//! ```
//!
//! after which both directions are length-prefixed frames:
//!
//! ```text
//! request := u32 len | u64 session | u8 flags | f32 × obs_dim obs
//!            (len == 9 + 4*obs_dim; flags bit0 = reset episode)
//! reply   := u32 len | u64 session | u64 version | f32 value
//!            | i32 × slots actions
//!            (len == 20 + 4*slots; version = weight snapshot version)
//! ```
//!
//! ## JSON debug mode
//!
//! If the first byte the client sends is `{` instead of the magic, the
//! connection switches to newline-delimited JSON. The server sends a
//! hello line `{"proto":"puffer-serve","obs_dim":N,"slots":K}`, then:
//!
//! ```text
//! request := {"session": N, "reset": bool, "obs": [f, ...]} "\n"
//! reply   := {"session": N, "version": V, "value": f, "actions": [i, ...]} "\n"
//! ```

use crate::util::json::{self, Json};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

/// First bytes of a binary-mode connection (client → server).
pub const CLIENT_MAGIC: &[u8; 4] = b"PUFB";
/// First bytes of the binary-mode hello (server → client).
pub const SERVER_MAGIC: &[u8; 4] = b"PUFS";
/// Hard cap on any framed payload; a length prefix above this is
/// treated as a corrupt stream rather than an allocation request.
pub const MAX_FRAME: usize = 16 << 20;

/// One observation submitted for inference.
#[derive(Clone, Debug, PartialEq)]
pub struct StepRequest {
    /// Client-chosen session id; recurrent state is keyed on it.
    pub session: u64,
    /// Episode boundary: zero this session's recurrent state before
    /// the forward that consumes this observation.
    pub reset: bool,
    /// Flattened observation row, exactly `obs_dim` wide.
    pub obs: Vec<f32>,
}

/// The action the policy chose for one [`StepRequest`].
#[derive(Clone, Debug, PartialEq)]
pub struct StepReply {
    /// Echoed from the request.
    pub session: u64,
    /// Monotone weight-snapshot version the forward ran with.
    pub version: u64,
    /// Critic value estimate for the observation.
    pub value: f32,
    /// Greedy action per head slot (MultiDiscrete layout).
    pub actions: Vec<i32>,
}

fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    // EOF is only clean at a frame boundary: nothing read yet.
    let mut got = 0;
    while got < buf.len() {
        let n = r.read(&mut buf[got..]).context("serve socket read")?;
        if n == 0 {
            ensure!(got == 0, "connection closed mid-frame ({got} of {} bytes)", buf.len());
            return Ok(false);
        }
        got += n;
    }
    Ok(true)
}

fn u32_le(b: &[u8]) -> u32 {
    u32::from_le_bytes([b[0], b[1], b[2], b[3]])
}

fn u64_le(b: &[u8]) -> u64 {
    u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]])
}

/// Read one length-prefixed frame payload. `Ok(None)` on clean EOF.
fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    if !read_exact_or_eof(r, &mut len_buf)? {
        return Ok(None);
    }
    let len = u32_le(&len_buf) as usize;
    ensure!(len <= MAX_FRAME, "frame length {len} exceeds the {MAX_FRAME}-byte cap");
    let mut payload = vec![0u8; len];
    ensure!(
        read_exact_or_eof(r, &mut payload)? || len == 0,
        "connection closed mid-frame (0 of {len} payload bytes)"
    );
    Ok(Some(payload))
}

fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    ensure!(payload.len() <= MAX_FRAME, "frame length {} exceeds the cap", payload.len());
    w.write_all(&(payload.len() as u32).to_le_bytes())
        .and_then(|_| w.write_all(payload))
        .context("serve socket write")
}

/// Server side: announce the model geometry after seeing [`CLIENT_MAGIC`].
pub fn write_hello(w: &mut impl Write, obs_dim: usize, slots: usize) -> Result<()> {
    let mut buf = Vec::with_capacity(12);
    buf.extend_from_slice(SERVER_MAGIC);
    buf.extend_from_slice(&(obs_dim as u32).to_le_bytes());
    buf.extend_from_slice(&(slots as u32).to_le_bytes());
    w.write_all(&buf).context("serve hello write")?;
    w.flush().context("serve hello flush")
}

/// Client side: read the hello, returning `(obs_dim, slots)`.
pub fn read_hello(r: &mut impl Read) -> Result<(usize, usize)> {
    let mut buf = [0u8; 12];
    ensure!(read_exact_or_eof(r, &mut buf)?, "server closed before hello");
    ensure!(&buf[..4] == SERVER_MAGIC, "bad server magic {:?}", &buf[..4]);
    Ok((u32_le(&buf[4..8]) as usize, u32_le(&buf[8..12]) as usize))
}

/// Encode a request as one binary frame.
pub fn write_request(w: &mut impl Write, req: &StepRequest) -> Result<()> {
    let mut payload = Vec::with_capacity(9 + 4 * req.obs.len());
    payload.extend_from_slice(&req.session.to_le_bytes());
    payload.push(req.reset as u8);
    for v in &req.obs {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    write_frame(w, &payload)
}

/// Decode one binary request frame; `Ok(None)` on clean EOF. The
/// observation width is enforced against the served model's `obs_dim`.
pub fn read_request(r: &mut impl Read, obs_dim: usize) -> Result<Option<StepRequest>> {
    let Some(payload) = read_frame(r)? else {
        return Ok(None);
    };
    let want = 9 + 4 * obs_dim;
    ensure!(
        payload.len() == want,
        "request frame is {} bytes, expected {want} (obs_dim {obs_dim})",
        payload.len()
    );
    let flags = payload[8];
    ensure!(flags <= 1, "unknown request flags {flags:#04x}");
    let obs = payload[9..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Some(StepRequest {
        session: u64_le(&payload[..8]),
        reset: flags & 1 != 0,
        obs,
    }))
}

/// Encode a reply as one binary frame.
pub fn write_reply(w: &mut impl Write, rep: &StepReply) -> Result<()> {
    let mut payload = Vec::with_capacity(20 + 4 * rep.actions.len());
    payload.extend_from_slice(&rep.session.to_le_bytes());
    payload.extend_from_slice(&rep.version.to_le_bytes());
    payload.extend_from_slice(&rep.value.to_le_bytes());
    for a in &rep.actions {
        payload.extend_from_slice(&a.to_le_bytes());
    }
    write_frame(w, &payload)
}

/// Decode one binary reply frame; `Ok(None)` on clean EOF.
pub fn read_reply(r: &mut impl Read, slots: usize) -> Result<Option<StepReply>> {
    let Some(payload) = read_frame(r)? else {
        return Ok(None);
    };
    let want = 20 + 4 * slots;
    ensure!(
        payload.len() == want,
        "reply frame is {} bytes, expected {want} (slots {slots})",
        payload.len()
    );
    let actions = payload[20..]
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Some(StepReply {
        session: u64_le(&payload[..8]),
        version: u64_le(&payload[8..16]),
        value: f32::from_le_bytes([payload[16], payload[17], payload[18], payload[19]]),
        actions,
    }))
}

/// JSON-mode hello line (no trailing newline; callers add it).
pub fn hello_json(obs_dim: usize, slots: usize) -> String {
    json::obj(vec![
        ("proto", json::s("puffer-serve")),
        ("obs_dim", json::num(obs_dim as f64)),
        ("slots", json::num(slots as f64)),
    ])
    .dump()
}

/// Parse one JSON-mode request line.
pub fn request_from_json(line: &str, obs_dim: usize) -> Result<StepRequest> {
    let j = Json::parse(line).context("serve JSON request")?;
    let session = j
        .get("session")
        .as_f64()
        .context("request needs a numeric \"session\"")? as u64;
    let reset = j.get("reset").as_bool().unwrap_or(false);
    let obs_arr = j
        .get("obs")
        .as_arr()
        .context("request needs an \"obs\" array")?;
    ensure!(
        obs_arr.len() == obs_dim,
        "request obs has {} values, expected {obs_dim}",
        obs_arr.len()
    );
    let mut obs = Vec::with_capacity(obs_arr.len());
    for (i, v) in obs_arr.iter().enumerate() {
        obs.push(v.as_f64().with_context(|| format!("obs[{i}] is not a number"))? as f32);
    }
    Ok(StepRequest { session, reset, obs })
}

/// Encode one JSON-mode request line (no trailing newline).
pub fn request_to_json(req: &StepRequest) -> String {
    json::obj(vec![
        ("session", json::num(req.session as f64)),
        ("reset", Json::Bool(req.reset)),
        ("obs", json::arr(req.obs.iter().map(|&v| json::num(v as f64)).collect())),
    ])
    .dump()
}

/// Encode one JSON-mode reply line (no trailing newline).
pub fn reply_to_json(rep: &StepReply) -> String {
    json::obj(vec![
        ("session", json::num(rep.session as f64)),
        ("version", json::num(rep.version as f64)),
        ("value", json::num(rep.value as f64)),
        ("actions", json::arr(rep.actions.iter().map(|&a| json::num(a as f64)).collect())),
    ])
    .dump()
}

/// Parse one JSON-mode reply line.
pub fn reply_from_json(line: &str) -> Result<StepReply> {
    let j = Json::parse(line).context("serve JSON reply")?;
    let field = |k: &str| -> Result<f64> {
        j.get(k)
            .as_f64()
            .with_context(|| format!("reply needs a numeric {k:?}"))
    };
    let actions_arr = j
        .get("actions")
        .as_arr()
        .context("reply needs an \"actions\" array")?;
    let mut actions = Vec::with_capacity(actions_arr.len());
    for (i, a) in actions_arr.iter().enumerate() {
        actions.push(a.as_f64().with_context(|| format!("actions[{i}] is not a number"))? as i32);
    }
    Ok(StepReply {
        session: field("session")? as u64,
        version: field("version")? as u64,
        value: field("value")? as f32,
        actions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(session: u64, reset: bool, obs: &[f32]) -> StepRequest {
        StepRequest { session, reset, obs: obs.to_vec() }
    }

    #[test]
    fn binary_request_round_trips() {
        let r = req(42, true, &[0.5, -1.25, 3.0]);
        let mut buf = Vec::new();
        write_request(&mut buf, &r).unwrap();
        assert_eq!(buf.len(), 4 + 9 + 12, "frame layout drifted");
        let back = read_request(&mut buf.as_slice(), 3).unwrap().unwrap();
        assert_eq!(back, r);
        // Clean EOF after the frame.
        let mut rest = &buf[buf.len()..];
        assert!(read_request(&mut rest, 3).unwrap().is_none());
    }

    #[test]
    fn binary_reply_round_trips() {
        let rep = StepReply { session: 7, version: 3, value: -0.125, actions: vec![2, 0] };
        let mut buf = Vec::new();
        write_reply(&mut buf, &rep).unwrap();
        assert_eq!(buf.len(), 4 + 20 + 8, "frame layout drifted");
        let back = read_reply(&mut buf.as_slice(), 2).unwrap().unwrap();
        assert_eq!(back, rep);
    }

    #[test]
    fn hello_round_trips() {
        let mut buf = Vec::new();
        write_hello(&mut buf, 11, 4).unwrap();
        assert_eq!(read_hello(&mut buf.as_slice()).unwrap(), (11, 4));
    }

    #[test]
    fn wrong_width_request_is_rejected() {
        let mut buf = Vec::new();
        write_request(&mut buf, &req(1, false, &[1.0, 2.0])).unwrap();
        let err = read_request(&mut buf.as_slice(), 5).unwrap_err().to_string();
        assert!(err.contains("expected"), "unhelpful error: {err}");
    }

    #[test]
    fn truncated_frame_is_an_error_not_eof() {
        let mut buf = Vec::new();
        write_request(&mut buf, &req(1, false, &[1.0])).unwrap();
        buf.truncate(buf.len() - 2);
        let err = read_request(&mut buf.as_slice(), 1).unwrap_err().to_string();
        assert!(err.contains("mid-frame"), "unhelpful error: {err}");
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let buf = (u32::MAX).to_le_bytes();
        let err = read_request(&mut buf.as_slice(), 1).unwrap_err().to_string();
        assert!(err.contains("cap"), "unhelpful error: {err}");
    }

    #[test]
    fn unknown_flags_are_rejected() {
        let r = req(1, false, &[1.0]);
        let mut buf = Vec::new();
        write_request(&mut buf, &r).unwrap();
        buf[4 + 8] = 0x80;
        let err = read_request(&mut buf.as_slice(), 1).unwrap_err().to_string();
        assert!(err.contains("flags"), "unhelpful error: {err}");
    }

    #[test]
    fn json_request_round_trips() {
        let r = req(9, true, &[0.0, 1.5]);
        let back = request_from_json(&request_to_json(&r), 2).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn json_reply_round_trips() {
        let rep = StepReply { session: 9, version: 12, value: 0.75, actions: vec![1] };
        let back = reply_from_json(&reply_to_json(&rep)).unwrap();
        assert_eq!(back, rep);
    }

    #[test]
    fn json_request_validates_width_and_types() {
        assert!(request_from_json(r#"{"session":1,"obs":[1,2,3]}"#, 2).is_err());
        assert!(request_from_json(r#"{"obs":[1,2]}"#, 2).is_err());
        // reset defaults to false.
        let r = request_from_json(r#"{"session":1,"obs":[1,2]}"#, 2).unwrap();
        assert!(!r.reset);
    }
}
