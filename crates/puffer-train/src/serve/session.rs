//! Per-session recurrent state: each client session owns an LSTM
//! `(h, c)` pair the size of the policy's hidden width. Sessions are
//! created lazily on first request, zeroed on episode boundaries (the
//! request's `reset` flag), and evicted once idle longer than the TTL.
//!
//! The table is owned by exactly one batcher shard (sessions are pinned
//! to shards by id), so it needs no interior locking — the concurrency
//! story lives in the request queue, not here.

use std::collections::HashMap;
use std::time::{Duration, Instant};

struct Session {
    h: Vec<f32>,
    c: Vec<f32>,
    last_used: Instant,
}

/// One shard's session store.
pub struct SessionTable {
    /// Hidden width per state vector; 0 for feedforward policies (the
    /// table then only tracks liveness for stats).
    state_dim: usize,
    ttl: Duration,
    sessions: HashMap<u64, Session>,
    last_sweep: Instant,
    /// Total sessions ever created (monotone; eviction does not undo it).
    created: u64,
    /// Total sessions evicted by the TTL.
    evicted: u64,
}

impl SessionTable {
    pub fn new(state_dim: usize, ttl: Duration) -> Self {
        SessionTable {
            state_dim,
            ttl,
            sessions: HashMap::new(),
            last_sweep: Instant::now(),
            created: 0,
            evicted: 0,
        }
    }

    /// Fetch session state for one request, applying the reset flag.
    /// Appends the session's `(h, c)` to the batch gather buffers and
    /// stamps it live. New sessions (and resets) contribute zeros —
    /// exactly what the trainer feeds at episode starts.
    pub fn gather(&mut self, id: u64, reset: bool, h_batch: &mut Vec<f32>, c_batch: &mut Vec<f32>) {
        let now = Instant::now();
        let sd = self.state_dim;
        let entry = self.sessions.entry(id).or_insert_with(|| {
            self.created += 1;
            Session {
                h: vec![0.0; sd],
                c: vec![0.0; sd],
                last_used: now,
            }
        });
        if reset {
            entry.h.iter_mut().for_each(|v| *v = 0.0);
            entry.c.iter_mut().for_each(|v| *v = 0.0);
        }
        entry.last_used = now;
        h_batch.extend_from_slice(&entry.h);
        c_batch.extend_from_slice(&entry.c);
    }

    /// Write one batch row's updated state back into a session. A
    /// session evicted between gather and scatter (impossible within a
    /// shard, but cheap to tolerate) is silently dropped.
    pub fn scatter(&mut self, id: u64, h_row: &[f32], c_row: &[f32]) {
        debug_assert_eq!(h_row.len(), self.state_dim);
        if let Some(s) = self.sessions.get_mut(&id) {
            s.h.copy_from_slice(h_row);
            s.c.copy_from_slice(c_row);
        }
    }

    /// Drop sessions idle past the TTL. Rate-limited to ~1 sweep/s so
    /// the scan never taxes the request path; pass `force` to sweep
    /// unconditionally (tests, shutdown accounting).
    pub fn evict_idle(&mut self, force: bool) -> usize {
        let now = Instant::now();
        if !force && now.duration_since(self.last_sweep) < Duration::from_secs(1) {
            return 0;
        }
        self.last_sweep = now;
        let ttl = self.ttl;
        let before = self.sessions.len();
        self.sessions
            .retain(|_, s| now.duration_since(s.last_used) < ttl);
        let gone = before - self.sessions.len();
        self.evicted += gone as u64;
        gone
    }

    /// Live session count.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Whether a session currently holds state (mostly for tests).
    pub fn contains(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    /// Sessions ever created on this shard.
    pub fn created(&self) -> u64 {
        self.created
    }

    /// Sessions evicted on this shard.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(ttl_ms: u64) -> SessionTable {
        SessionTable::new(2, Duration::from_millis(ttl_ms))
    }

    #[test]
    fn new_sessions_start_zeroed_and_persist_state() {
        let mut t = table(10_000);
        let (mut h, mut c) = (Vec::new(), Vec::new());
        t.gather(7, false, &mut h, &mut c);
        assert_eq!(h, vec![0.0, 0.0]);
        assert_eq!(c, vec![0.0, 0.0]);
        t.scatter(7, &[1.0, 2.0], &[3.0, 4.0]);
        let (mut h, mut c) = (Vec::new(), Vec::new());
        t.gather(7, false, &mut h, &mut c);
        assert_eq!(h, vec![1.0, 2.0]);
        assert_eq!(c, vec![3.0, 4.0]);
        assert_eq!(t.created(), 1, "touching is not creating");
    }

    #[test]
    fn reset_zeroes_state_without_dropping_the_session() {
        let mut t = table(10_000);
        let (mut h, mut c) = (Vec::new(), Vec::new());
        t.gather(7, false, &mut h, &mut c);
        t.scatter(7, &[1.0, 2.0], &[3.0, 4.0]);
        let (mut h, mut c) = (Vec::new(), Vec::new());
        t.gather(7, true, &mut h, &mut c);
        assert_eq!(h, vec![0.0, 0.0], "reset must zero h");
        assert_eq!(c, vec![0.0, 0.0], "reset must zero c");
        assert_eq!(t.created(), 1);
    }

    #[test]
    fn idle_sessions_are_evicted_and_recreated_fresh() {
        let mut t = table(0); // everything is instantly idle
        let (mut h, mut c) = (Vec::new(), Vec::new());
        t.gather(1, false, &mut h, &mut c);
        t.scatter(1, &[9.0, 9.0], &[9.0, 9.0]);
        assert_eq!(t.evict_idle(true), 1);
        assert!(!t.contains(1));
        assert_eq!(t.evicted(), 1);
        // The same id comes back zeroed, not with its old state.
        let (mut h, mut c) = (Vec::new(), Vec::new());
        t.gather(1, false, &mut h, &mut c);
        assert_eq!(h, vec![0.0, 0.0]);
        assert_eq!(t.created(), 2);
    }

    #[test]
    fn live_sessions_survive_the_sweep() {
        let mut t = table(60_000);
        let (mut h, mut c) = (Vec::new(), Vec::new());
        t.gather(1, false, &mut h, &mut c);
        t.gather(2, false, &mut h, &mut c);
        assert_eq!(t.evict_idle(true), 0);
        assert_eq!(t.len(), 2);
    }
}
