//! **Bench P1** — PJRT runtime latency/throughput for every entry point:
//! forward (both batch sizes), the Pallas GAE kernel, and the full PPO
//! train step. This is the learner-side hot path the trainer drives; the
//! §Perf targets in EXPERIMENTS.md come from here.
//!
//! `cargo bench --bench runtime`; `PUFFER_BENCH_SECS` per entry.

use pufferlib::runtime::*;
use pufferlib::util::stats::{percentile, Welford};
use std::time::Instant;

fn bench_entry(
    label: &str,
    reps_budget_secs: f64,
    mut run: impl FnMut() -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    // Warmup.
    for _ in 0..3 {
        run()?;
    }
    let mut lat = Welford::new();
    let mut samples = Vec::new();
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < reps_budget_secs {
        let s = Instant::now();
        run()?;
        let us = s.elapsed().as_secs_f64() * 1e6;
        lat.push(us);
        samples.push(us);
    }
    println!(
        "| {:<22} | {:>9.0} | {:>9.0} | {:>9.0} | {:>7} |",
        label,
        lat.mean(),
        percentile(&samples, 50.0),
        percentile(&samples, 99.0),
        lat.count()
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let secs: f64 = std::env::var("PUFFER_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0);

    let mut rt = Runtime::new("artifacts")?;
    let spec = rt.manifest().spec("ocean_squared")?.clone();
    let (bf, br, t, d) = (spec.batch_fwd, spec.batch_roll, spec.horizon, spec.obs_dim);
    let n = t * br;
    let params = vec![0.01f32; spec.n_params];

    println!("# Bench P1 — PJRT entry-point latency (ocean_squared spec: obs {d}, {} params)", spec.n_params);
    println!(
        "| {:<22} | {:>9} | {:>9} | {:>9} | {:>7} |",
        "entry", "mean µs", "p50 µs", "p99 µs", "reps"
    );
    println!(
        "|{}|{}|{}|{}|{}|",
        "-".repeat(24),
        "-".repeat(11),
        "-".repeat(11),
        "-".repeat(11),
        "-".repeat(9)
    );

    // forward at both batch sizes
    for b in [bf, br] {
        let exe = rt.load("ocean_squared", &format!("forward_b{b}"))?;
        let obs = vec![0.1f32; b * d];
        bench_entry(&format!("forward_b{b}"), secs, || {
            let out = exe.run(&[lit_f32(&params), lit_f32_2d(&obs, b, d)?])?;
            std::hint::black_box(&out);
            Ok(())
        })?;
    }

    // GAE (Pallas kernel)
    {
        let exe = rt.load("ocean_squared", "gae")?;
        let z = vec![0.1f32; n];
        let lv = vec![0.0f32; br];
        bench_entry("gae (pallas)", secs, || {
            let out = exe.run(&[
                lit_f32_2d(&z, t, br)?,
                lit_f32_2d(&z, t, br)?,
                lit_f32_2d(&z, t, br)?,
                lit_f32(&lv),
            ])?;
            std::hint::black_box(&out);
            Ok(())
        })?;
    }

    // train_step (full PPO update, fused MLP fwd+bwd + Adam)
    {
        let exe = rt.load("ocean_squared", "train_step")?;
        let obs = vec![0.1f32; n * d];
        let actions = vec![0i32; n];
        let zn = vec![0.0f32; n];
        let m = vec![0.0f32; spec.n_params];
        bench_entry("train_step", secs.max(3.0), || {
            let out = exe.run(&[
                lit_f32(&params),
                lit_f32(&m),
                lit_f32(&m),
                lit_scalar(0.0),
                lit_scalar(1e-3),
                lit_scalar(0.01),
                lit_f32_2d(&obs, n, d)?,
                lit_i32_2d(&actions, n, 1)?,
                lit_f32(&zn),
                lit_f32(&zn),
                lit_f32(&zn),
            ])?;
            std::hint::black_box(&out);
            Ok(())
        })?;
    }

    println!("\n# derived: forward_b{bf} rows/s and train_step steps/s set the");
    println!("# learner ceiling; compare against rollout SPS in bench T2.");
    Ok(())
}
