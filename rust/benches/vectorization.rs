//! **Bench T2 + C4** — reproduces the paper's Table 2: vectorized
//! throughput of PufferLib (sync), Puffer Pool (EnvPool), and the
//! Gymnasium / SB3 baseline designs, across the profiled environments.
//!
//! One host column (the paper had desktop + laptop); the quantity that
//! must reproduce is the *ordering and ratios* between implementations,
//! not absolute SPS — see EXPERIMENTS.md.
//!
//! `cargo bench --bench vectorization [-- env-substring]`
//! `PUFFER_BENCH_SECS` per-cell budget (default 2.0).

use pufferlib::emulation::FlatEnv;
use pufferlib::envs;
use pufferlib::vector::autotune::measure;
use pufferlib::vector::baselines::{GymnasiumVec, Sb3Vec};
use pufferlib::vector::{Multiprocessing, VecConfig, VecEnv};
use std::sync::Arc;

type Factory = Arc<dyn Fn(usize) -> Box<dyn FlatEnv> + Send + Sync>;

/// (display name, factory, num_envs, workers). Slow sims are time-scaled
/// (relative comparisons unaffected; DESIGN.md §Substitutions).
fn workloads() -> Vec<(&'static str, Factory, usize, usize)> {
    fn scaled(name: &'static str, scale: f64) -> Factory {
        Arc::new(move |i| envs::profile::make_profile_scaled(name, i as u64, scale))
    }
    fn plain(name: &'static str) -> Factory {
        Arc::new(move |i| envs::make(name, i as u64))
    }
    vec![
        ("Neural MMO", scaled("nmmo", 0.1), 4, 4),
        ("Nethack", scaled("nethack", 1.0), 8, 4),
        ("Minihack", scaled("minihack", 1.0), 8, 4),
        ("Pokemon Red", scaled("pokemon", 0.1), 8, 4),
        ("Cartpole", plain("classic/cartpole"), 8, 4),
        ("Ocean Squared", plain("ocean/squared"), 8, 4),
        ("Procgen Bigfish", scaled("procgen", 1.0), 8, 4),
        ("Atari Breakout", scaled("atari", 0.25), 8, 4),
        ("Crafter", scaled("crafter", 0.05), 8, 4),
        ("Minigrid", scaled("minigrid", 1.0), 8, 4),
    ]
}

fn cell(factory: &Factory, backend: &str, num_envs: usize, workers: usize, secs: f64) -> Option<f64> {
    let f = factory.clone();
    let mk = move |i: usize| f(i);
    let sync_cfg = VecConfig {
        num_envs,
        num_workers: workers,
        batch_size: num_envs,
        ..Default::default()
    };
    let pool_cfg = VecConfig {
        num_envs,
        num_workers: workers,
        batch_size: num_envs / 2,
        ..Default::default()
    };
    let res = match backend {
        "puffer" => Multiprocessing::new(mk, sync_cfg).ok().map(|v| measure(v, secs)),
        "pool" => {
            if pool_cfg.mode().is_err() {
                return None;
            }
            Multiprocessing::new(mk, pool_cfg).ok().map(|v| measure(v, secs))
        }
        "gymnasium" => GymnasiumVec::new(mk, sync_cfg).ok().map(|v| measure(v, secs)),
        "sb3" => Sb3Vec::new(mk, sync_cfg).ok().map(|v| measure(v, secs)),
        _ => unreachable!(),
    };
    res.and_then(|r| r.ok())
}

fn main() {
    let secs: f64 = std::env::var("PUFFER_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2.0);
    let filter: Option<String> = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .map(|s| s.to_lowercase());

    println!("# Bench T2 — vectorized throughput (env-steps/sec), one host");
    println!("# paper Table 2; time-scaled sims marked (×s) in EXPERIMENTS.md");
    println!(
        "| {:<16} | {:>10} | {:>11} | {:>10} | {:>10} | {:>5} |",
        "Environment", "PufferLib", "Puffer Pool", "Gymnasium", "SB3", "best"
    );
    println!(
        "|{}|{}|{}|{}|{}|{}|",
        "-".repeat(18),
        "-".repeat(12),
        "-".repeat(13),
        "-".repeat(12),
        "-".repeat(12),
        "-".repeat(7)
    );

    for (name, factory, num_envs, workers) in workloads() {
        if let Some(f) = &filter {
            if !name.to_lowercase().contains(f.as_str()) {
                continue;
            }
        }
        let puffer = cell(&factory, "puffer", num_envs, workers, secs);
        let pool = cell(&factory, "pool", num_envs, workers, secs);
        let gym = cell(&factory, "gymnasium", num_envs, workers, secs);
        let sb3 = cell(&factory, "sb3", num_envs, workers, secs);
        let fmt = |x: Option<f64>| x.map(|v| format!("{v:.0}")).unwrap_or_else(|| "-".into());
        let best = [("puffer", puffer), ("pool", pool), ("gym", gym), ("sb3", sb3)]
            .into_iter()
            .filter_map(|(n, v)| v.map(|v| (n, v)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(n, _)| n)
            .unwrap_or("-");
        println!(
            "| {:<16} | {:>10} | {:>11} | {:>10} | {:>10} | {:>5} |",
            name,
            fmt(puffer),
            fmt(pool),
            fmt(gym),
            fmt(sb3),
            best
        );
    }
    println!("\n# C4 note: pokemon row ≈ the paper's §7 Pokémon Red training workload;");
    println!("# compare Puffer Pool vs SB3 columns for the claimed 2-3x.");
}
