//! Binary checkpoints: flat params + Adam state + counters. Format:
//! magic, version, spec-key, then length-prefixed f32 arrays, all
//! little-endian — no serde needed, stable across runs.

use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PUFFCKPT";
const VERSION: u32 = 1;

/// Everything needed to resume training.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub spec_key: String,
    pub global_step: u64,
    pub params: Vec<f32>,
    pub adam_m: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub adam_step: f32,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        let key = self.spec_key.as_bytes();
        f.write_all(&(key.len() as u32).to_le_bytes())?;
        f.write_all(key)?;
        f.write_all(&self.global_step.to_le_bytes())?;
        f.write_all(&self.adam_step.to_le_bytes())?;
        for arr in [&self.params, &self.adam_m, &self.adam_v] {
            f.write_all(&(arr.len() as u64).to_le_bytes())?;
            for x in arr.iter() {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a puffer checkpoint");
        let mut u32b = [0u8; 4];
        f.read_exact(&mut u32b)?;
        anyhow::ensure!(u32::from_le_bytes(u32b) == VERSION, "checkpoint version mismatch");
        f.read_exact(&mut u32b)?;
        let key_len = u32::from_le_bytes(u32b) as usize;
        let mut key = vec![0u8; key_len];
        f.read_exact(&mut key)?;
        let mut u64b = [0u8; 8];
        f.read_exact(&mut u64b)?;
        let global_step = u64::from_le_bytes(u64b);
        f.read_exact(&mut u32b)?;
        let adam_step = f32::from_le_bytes(u32b);
        let read_arr = |f: &mut std::fs::File| -> Result<Vec<f32>> {
            let mut lenb = [0u8; 8];
            f.read_exact(&mut lenb)?;
            let len = u64::from_le_bytes(lenb) as usize;
            let mut bytes = vec![0u8; len * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect())
        };
        let params = read_arr(&mut f)?;
        let adam_m = read_arr(&mut f)?;
        let adam_v = read_arr(&mut f)?;
        Ok(Checkpoint {
            spec_key: String::from_utf8(key).context("bad spec key")?,
            global_step,
            params,
            adam_m,
            adam_v,
            adam_step,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let ck = Checkpoint {
            spec_key: "ocean_squared".into(),
            global_step: 12_345,
            params: vec![1.5, -2.0, 0.25],
            adam_m: vec![0.1, 0.2, 0.3],
            adam_v: vec![0.0; 3],
            adam_step: 7.0,
        };
        let dir = std::env::temp_dir().join("puffer_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("puffer_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }
}
