//! Clean PuffeRL (paper §6): the first-party PPO trainer. Heavily
//! customized in the same ways the paper describes — separate train/eval,
//! model checkpointing, fast LSTM support, asynchronous environment
//! simulation (EnvPool), episode-stat logging, and multiagent support —
//! driving the learner math through the [`crate::backend::PolicyBackend`]
//! abstraction (pure-Rust `NativeBackend` by default, AOT/PJRT behind the
//! `pjrt` feature). Python never runs here.

mod checkpoint;
mod rollout;
mod trainer;

pub use checkpoint::Checkpoint;
pub use rollout::{collect_rollout, EpisodeLog, RolloutBuffer};
pub use trainer::{EvalReport, TrainConfig, TrainReport, Trainer};
