//! Clean PuffeRL (paper §6): the first-party PPO trainer. Heavily
//! customized in the same ways the paper describes — separate train/eval,
//! model checkpointing, fast LSTM support, asynchronous environment
//! simulation (EnvPool), episode-stat logging, and multiagent support —
//! driving the AOT-compiled L2 train step through PJRT. Python never runs
//! here.

mod checkpoint;
mod rollout;
mod trainer;

pub use checkpoint::Checkpoint;
pub use rollout::{collect_rollout, EpisodeLog, RolloutBuffer};
pub use trainer::{EvalReport, TrainConfig, TrainReport, Trainer};
