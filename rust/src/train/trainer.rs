//! The PPO training loop: rollouts → GAE → train_step × epochs, with LR
//! annealing, checkpointing, and CSV/console metric logging — all through
//! the [`PolicyBackend`] abstraction, so the same loop drives the pure-
//! Rust [`NativeBackend`] (default) and the AOT/PJRT path (`pjrt`
//! feature).

use super::rollout::{collect_rollout, EpisodeLog, RolloutBuffer};
use super::Checkpoint;
use crate::backend::{AdamState, NativeBackend, PolicyBackend, TrainBatch};
use crate::policy::Policy;
use crate::util::timer::SpsCounter;
use crate::vector::{Multiprocessing, Serial, VecConfig, VecEnv};
use crate::wrappers::{EnvSpec, WrapperSpec};
use anyhow::Result;
use std::io::Write as _;

/// Training configuration (Clean PuffeRL's YAML keys, as a struct; see
/// [`crate::config`] for the file/CLI layer).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// First-party env name, e.g. "ocean/squared".
    pub env: String,
    /// Wrapper chain applied over the env, innermost first (the
    /// `train.wrap.*` config keys / `--wrap.*` CLI overrides). The whole
    /// pipeline — probe, backend spec, vectorizer slabs — sizes itself
    /// from the wrapped geometry.
    pub wrappers: Vec<WrapperSpec>,
    /// Total environment interactions to train for.
    pub total_steps: u64,
    pub lr: f32,
    pub ent_coef: f32,
    /// PPO epochs per rollout segment.
    pub epochs: usize,
    pub anneal_lr: bool,
    pub seed: u64,
    /// Worker threads for the vectorizer (0 = serial backend).
    pub num_workers: usize,
    /// EnvPool mode: recv half the envs per batch (M = 2N
    /// double-buffering). Requires `num_workers >= 2`.
    pub pool: bool,
    /// Optional run directory for metrics.csv + checkpoints.
    pub run_dir: Option<String>,
    /// Console log every n segments (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            env: "ocean/squared".into(),
            wrappers: Vec::new(),
            total_steps: 30_000,
            lr: 2.5e-3,
            ent_coef: 0.01,
            epochs: 4,
            anneal_lr: true,
            seed: 1,
            num_workers: 2,
            pool: false,
            run_dir: None,
            log_every: 5,
        }
    }
}

/// Final report from a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub global_step: u64,
    pub sps: f64,
    pub mean_score: Option<f64>,
    pub mean_return: Option<f64>,
    pub episodes: usize,
    pub last_loss: f32,
    /// (global_step, mean_score) curve sampled once per segment.
    pub score_curve: Vec<(u64, f64)>,
}

/// Report from an evaluation run.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub episodes: usize,
    pub mean_score: Option<f64>,
    pub mean_return: Option<f64>,
}

/// Clean PuffeRL.
pub struct Trainer {
    cfg: TrainConfig,
    backend: Box<dyn PolicyBackend>,
    policy: Policy,
    venv: Box<dyn VecEnv>,
    buf: RolloutBuffer,
    log: EpisodeLog,
    spec_key: String,
    opt: AdamState,
    global_step: u64,
    metrics_file: Option<std::fs::File>,
}

impl Trainer {
    /// The env + wrapper-chain spec this config describes — what every
    /// construction path (probe, backend, vectorizer) builds from.
    fn env_spec(cfg: &TrainConfig) -> EnvSpec {
        EnvSpec::new(cfg.env.as_str()).with_wrappers(cfg.wrappers.iter().cloned())
    }

    /// Train with the default pure-Rust [`NativeBackend`]: no artifacts,
    /// no Python, no native dependencies. The backend spec is sized from
    /// the *wrapped* env (stacking widens `obs_dim`), and its key embeds
    /// the wrapper chain so checkpoints never cross chains silently.
    pub fn native(cfg: TrainConfig) -> Result<Self> {
        let spec = Self::env_spec(&cfg);
        let probe = spec.build(0);
        let backend = NativeBackend::for_env(&spec.key(), probe.as_ref())?;
        Self::build(cfg, Box::new(backend), probe)
    }

    /// Train through the AOT/PJRT path (requires the `pjrt` feature and
    /// `make artifacts`).
    #[cfg(feature = "pjrt")]
    pub fn pjrt(cfg: TrainConfig, artifacts_dir: &str) -> Result<Self> {
        anyhow::ensure!(
            cfg.wrappers.is_empty(),
            "the pjrt backend executes AOT-compiled specs with fixed shapes; \
             wrapper chains are supported on the native backend only for now"
        );
        let key = crate::runtime::Manifest::spec_key_for_env(&cfg.env);
        let backend = crate::backend::PjrtBackend::new(artifacts_dir, &key)?;
        Self::with_backend(cfg, Box::new(backend))
    }

    /// Train with any [`PolicyBackend`].
    pub fn with_backend(cfg: TrainConfig, backend: Box<dyn PolicyBackend>) -> Result<Self> {
        let probe = Self::env_spec(&cfg).build(0);
        Self::build(cfg, backend, probe)
    }

    fn build(
        cfg: TrainConfig,
        mut backend: Box<dyn PolicyBackend>,
        probe: Box<dyn crate::emulation::FlatEnv>,
    ) -> Result<Self> {
        let spec = backend.spec().clone();
        let spec_key = backend.key().to_string();

        // Contract check against the probe env: shape drift between the
        // backend spec and the Rust env fails loudly here.
        anyhow::ensure!(
            spec.obs_dim == probe.obs_layout().flat_len(),
            "spec '{spec_key}': obs_dim {} != env flat obs len {}",
            spec.obs_dim,
            probe.obs_layout().flat_len()
        );
        anyhow::ensure!(
            spec.act_dims == probe.action_dims(),
            "spec '{spec_key}': act_dims {:?} != env action dims {:?}",
            spec.act_dims,
            probe.action_dims()
        );
        anyhow::ensure!(
            spec.agents == probe.num_agents(),
            "spec '{spec_key}': agents {} != env num_agents {}",
            spec.agents,
            probe.num_agents()
        );
        drop(probe);

        let agents = spec.agents;
        anyhow::ensure!(
            spec.batch_roll % agents == 0,
            "batch_roll {} not divisible by agents {agents}",
            spec.batch_roll
        );
        let num_envs = spec.batch_roll / agents;

        // Vectorizer: sync (batch = all) or pooled (batch = half, M = 2N).
        // Built from the same EnvSpec as the probe, so the worker slabs
        // use the wrapped layout.
        let env_spec = Self::env_spec(&cfg);
        let venv: Box<dyn VecEnv> = if cfg.num_workers == 0 {
            Box::new(Serial::from_spec(
                &env_spec,
                VecConfig {
                    num_envs,
                    num_workers: 1,
                    batch_size: num_envs,
                    seed: cfg.seed,
                    ..Default::default()
                },
            )?)
        } else {
            let workers = pick_workers(num_envs, cfg.num_workers, cfg.pool);
            let batch = if cfg.pool { num_envs / 2 } else { num_envs };
            Box::new(Multiprocessing::from_spec(
                &env_spec,
                VecConfig {
                    num_envs,
                    num_workers: workers,
                    batch_size: batch,
                    seed: cfg.seed,
                    ..Default::default()
                },
            )?)
        };
        if cfg.pool {
            anyhow::ensure!(
                spec.batch_fwd * 2 == spec.batch_roll,
                "pool mode needs batch_roll == 2 * batch_fwd"
            );
        }

        let policy = Policy::new(backend.as_mut(), cfg.seed)?;
        let buf = RolloutBuffer::new(
            spec.horizon,
            spec.batch_roll,
            spec.obs_dim,
            spec.act_dims.len(),
        );

        let metrics_file = match &cfg.run_dir {
            Some(dir) => {
                std::fs::create_dir_all(dir)?;
                let mut f = std::fs::File::create(format!("{dir}/metrics.csv"))?;
                writeln!(
                    f,
                    "global_step,sps,score,ep_return,ep_length,loss,pg_loss,v_loss,entropy,approx_kl"
                )?;
                Some(f)
            }
            None => None,
        };

        Ok(Trainer {
            cfg,
            backend,
            policy,
            venv,
            buf,
            log: EpisodeLog::default(),
            spec_key,
            opt: AdamState::new(spec.n_params),
            global_step: 0,
            metrics_file,
        })
    }

    pub fn policy(&self) -> &Policy {
        &self.policy
    }
    pub fn global_step(&self) -> u64 {
        self.global_step
    }

    /// Run the full training loop.
    pub fn train(&mut self) -> Result<TrainReport> {
        let spec = self.policy.spec().clone();
        let t_dim = spec.horizon;
        let r_dim = spec.batch_roll;
        let n = t_dim * r_dim;
        let mut sps = SpsCounter::new();
        let mut last_metrics = [0.0f32; 5];
        let mut segment = 0usize;
        let mut score_curve = Vec::new();

        self.venv.async_reset(self.cfg.seed);
        self.buf.mark_all_starts();
        self.policy.reset_all_state();

        while self.global_step < self.cfg.total_steps {
            // ---- Rollout ----
            let (policy, backend, venv, buf, log) = (
                &mut self.policy,
                &mut *self.backend,
                &mut *self.venv,
                &mut self.buf,
                &mut self.log,
            );
            let mut dyn_venv = VenvRef(venv);
            collect_rollout(&mut dyn_venv, buf, log, |obs, rows, done_rows| {
                // Zero recurrent state for rows whose episode just ended
                // *before* the forward pass on their fresh observations —
                // the LSTM state-reset discipline of paper §3.4.
                for &r in done_rows {
                    policy.reset_state(r);
                }
                policy.step(&mut *backend, obs, rows)
            })?;
            self.global_step += n as u64;
            sps.add(n as u64);

            // ---- GAE ----
            let (adv, ret) = self.backend.gae(
                &self.buf.rewards,
                &self.buf.values,
                &self.buf.dones,
                &self.buf.last_values,
            )?;

            // ---- PPO epochs ----
            let lr = if self.cfg.anneal_lr {
                let frac = 1.0 - self.global_step as f32 / self.cfg.total_steps as f32;
                self.cfg.lr * frac.max(0.05)
            } else {
                self.cfg.lr
            };
            for _ in 0..self.cfg.epochs {
                let batch = TrainBatch {
                    t: t_dim,
                    r: r_dim,
                    obs: &self.buf.obs,
                    starts: &self.buf.starts,
                    actions: &self.buf.actions,
                    logp: &self.buf.logp,
                    adv: &adv,
                    ret: &ret,
                };
                last_metrics = self.backend.train_step(
                    self.policy.params_mut(),
                    &mut self.opt,
                    lr,
                    self.cfg.ent_coef,
                    &batch,
                )?;
            }

            // ---- Logging ----
            segment += 1;
            if let Some(s) = self.log.mean_score(100) {
                score_curve.push((self.global_step, s));
            }
            let window_sps = sps.window();
            if self.cfg.log_every > 0 && segment % self.cfg.log_every == 0 {
                println!(
                    "[{}] step {:>8}  sps {:>8.0}  score {:>6}  return {:>8}  loss {:>8.4}  kl {:>7.4}",
                    self.cfg.env,
                    self.global_step,
                    window_sps,
                    fmt_opt(self.log.mean_score(100)),
                    fmt_opt(self.log.mean_return(100)),
                    last_metrics[0],
                    last_metrics[4],
                );
            }
            if let Some(f) = &mut self.metrics_file {
                writeln!(
                    f,
                    "{},{:.0},{},{},{},{},{},{},{},{}",
                    self.global_step,
                    window_sps,
                    fmt_opt(self.log.mean_score(100)),
                    fmt_opt(self.log.mean_return(100)),
                    fmt_opt(self.log.mean_length(100)),
                    last_metrics[0],
                    last_metrics[1],
                    last_metrics[2],
                    last_metrics[3],
                    last_metrics[4],
                )?;
            }
        }

        if let Some(dir) = &self.cfg.run_dir {
            self.checkpoint().save(format!("{dir}/checkpoint.bin"))?;
        }

        Ok(TrainReport {
            global_step: self.global_step,
            sps: sps.overall(),
            mean_score: self.log.mean_score(100),
            mean_return: self.log.mean_return(100),
            episodes: self.log.scores.len(),
            last_loss: last_metrics[0],
            score_curve,
        })
    }

    /// Evaluate the current policy (stochastic sampling, fresh envs) for
    /// `min_episodes` episodes.
    pub fn eval(&mut self, min_episodes: usize) -> Result<EvalReport> {
        let mut log = EpisodeLog::default();
        self.venv.async_reset(self.cfg.seed ^ 0xEEEE);
        self.policy.reset_all_state();
        let agents = self.venv.agents_per_env();
        let slots = self.venv.action_dims().len();
        let layout = self.venv.obs_layout().clone();
        let d = layout.flat_len();
        while log.scores.len() < min_episodes {
            let (raw_obs, env_ids, terms, truncs, infos) = {
                let b = self.venv.recv()?;
                (
                    b.obs.to_vec(),
                    b.env_ids.to_vec(),
                    b.terms.to_vec(),
                    b.truncs.to_vec(),
                    b.infos,
                )
            };
            log.absorb(&infos);
            let mut global_rows = Vec::new();
            for &e in &env_ids {
                for a in 0..agents {
                    global_rows.push(e * agents + a);
                }
            }
            let rows = global_rows.len();
            // Eval-side recurrent reset: done flags arrive with the batch;
            // rows whose episode just ended get fresh obs (auto-reset), so
            // their LSTM state must be zeroed before the forward pass —
            // the same discipline the training rollout applies.
            for (i, &g) in global_rows.iter().enumerate() {
                if terms[i] || truncs[i] {
                    self.policy.reset_state(g);
                }
            }
            let mut obs_f32 = vec![0.0; rows * d];
            for (i, row) in raw_obs.chunks_exact(layout.byte_len()).enumerate() {
                layout.row_to_f32(row, &mut obs_f32[i * d..(i + 1) * d]);
            }
            let out = self.policy.step(&mut *self.backend, &obs_f32, &global_rows)?;
            self.venv.send(&out.actions[..rows * slots])?;
        }
        Ok(EvalReport {
            episodes: log.scores.len(),
            mean_score: log.mean_score(usize::MAX),
            mean_return: log.mean_return(usize::MAX),
        })
    }

    /// Snapshot trainer state.
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            spec_key: self.spec_key.clone(),
            global_step: self.global_step,
            params: self.policy.params().to_vec(),
            adam_m: self.opt.m.clone(),
            adam_v: self.opt.v.clone(),
            adam_step: self.opt.step,
        }
    }

    /// Restore from a checkpoint (spec must match).
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        anyhow::ensure!(
            ck.spec_key == self.spec_key,
            "checkpoint is for '{}', trainer is '{}'",
            ck.spec_key,
            self.spec_key
        );
        anyhow::ensure!(
            ck.params.len() == self.policy.spec().n_params,
            "checkpoint '{}' has {} params, this backend expects {} — was it \
             written by a backend with a different architecture (e.g. a \
             recurrent pjrt spec vs the feedforward native spec)?",
            ck.spec_key,
            ck.params.len(),
            self.policy.spec().n_params
        );
        anyhow::ensure!(
            ck.adam_m.len() == ck.params.len() && ck.adam_v.len() == ck.params.len(),
            "checkpoint optimizer state length does not match its params"
        );
        *self.policy.params_mut() = ck.params.clone();
        self.opt.m = ck.adam_m.clone();
        self.opt.v = ck.adam_v.clone();
        self.opt.step = ck.adam_step;
        self.global_step = ck.global_step;
        Ok(())
    }
}

fn fmt_opt(x: Option<f64>) -> String {
    match x {
        Some(v) => format!("{v:.3}"),
        None => "-".into(),
    }
}

/// Pick a worker count ≤ `want` that divides `num_envs` (and keeps the
/// pool batch a multiple of envs-per-worker when pooling).
fn pick_workers(num_envs: usize, want: usize, pool: bool) -> usize {
    let mut best = 1;
    for w in 1..=want.min(num_envs) {
        if num_envs % w != 0 {
            continue;
        }
        let epw = num_envs / w;
        if pool && (num_envs / 2) % epw != 0 {
            continue;
        }
        best = w;
    }
    best
}

/// Adapter so `collect_rollout` (generic over `V: VecEnv`) can take the
/// boxed trait object.
struct VenvRef<'a>(&'a mut dyn VecEnv);
impl crate::vector::VecEnv for VenvRef<'_> {
    fn obs_layout(&self) -> &crate::spaces::StructLayout {
        self.0.obs_layout()
    }
    fn action_dims(&self) -> &[usize] {
        self.0.action_dims()
    }
    fn agents_per_env(&self) -> usize {
        self.0.agents_per_env()
    }
    fn num_envs(&self) -> usize {
        self.0.num_envs()
    }
    fn batch_size(&self) -> usize {
        self.0.batch_size()
    }
    fn async_reset(&mut self, seed: u64) {
        self.0.async_reset(seed)
    }
    fn recv(&mut self) -> Result<crate::vector::StepBatch<'_>> {
        self.0.recv()
    }
    fn send(&mut self, actions: &[i32]) -> Result<()> {
        self.0.send(actions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_workers_respects_divisibility() {
        assert_eq!(pick_workers(32, 4, false), 4);
        assert_eq!(pick_workers(32, 4, true), 4);
        assert_eq!(pick_workers(30, 4, false), 3);
        assert_eq!(pick_workers(7, 4, false), 1);
        // pool: batch 16, envs 32, w=4 → epw 8, 16 % 8 == 0 ✓
        assert_eq!(pick_workers(32, 3, true), 2);
    }

    #[test]
    fn trainer_sizes_backend_from_wrapped_spec() {
        let bare = crate::envs::make("ocean/squared", 0);
        let bare_dim = bare.obs_layout().flat_len();
        drop(bare);
        let cfg = TrainConfig {
            env: "ocean/squared".into(),
            wrappers: vec![WrapperSpec::ClipReward(1.0), WrapperSpec::Stack(4)],
            total_steps: 0, // construct only
            log_every: 0,
            ..Default::default()
        };
        let t = Trainer::native(cfg).unwrap();
        assert_eq!(t.policy().spec().obs_dim, 4 * bare_dim);
        // The chain is part of the checkpoint key: a differently-wrapped
        // run can never silently restore these params.
        assert!(t.spec_key.contains("stack=4"), "{}", t.spec_key);
    }

    #[test]
    fn native_trainer_constructs_for_every_ocean_env() {
        for env in crate::envs::OCEAN_ENVS {
            let cfg = TrainConfig {
                env: env.to_string(),
                total_steps: 0, // construct only
                log_every: 0,
                ..Default::default()
            };
            let t = Trainer::native(cfg).unwrap_or_else(|e| panic!("{env}: {e}"));
            assert_eq!(t.policy().params().len(), t.policy().spec().n_params);
        }
    }
}
