//! Configuration: a YAML-subset file format plus `--key.path=value` CLI
//! overrides (Clean PuffeRL ships "clean YAML configs" with a runner CLI;
//! serde is unavailable offline, so the parser lives here).
//!
//! Supported YAML subset: nested maps by 2-space indentation, scalar
//! values (bool/int/float/string), `#` comments, blank lines. That covers
//! every config this project ships; anything else is a parse error.

mod yaml;

pub use yaml::{parse_yaml, YamlError};

use crate::train::TrainConfig;
use std::collections::BTreeMap;

/// A flat key→scalar view of a config tree ("train.lr" → "0.0025").
pub type FlatConfig = BTreeMap<String, String>;

/// Apply `--a.b=c`-style CLI overrides onto a flat config. Returns the
/// list of unrecognized args (for the caller to reject or pass on).
pub fn apply_overrides<'a>(
    cfg: &mut FlatConfig,
    args: impl Iterator<Item = &'a str>,
) -> Vec<String> {
    let mut rest = Vec::new();
    for arg in args {
        if let Some(body) = arg.strip_prefix("--") {
            if let Some((k, v)) = body.split_once('=') {
                cfg.insert(k.to_string(), v.to_string());
                continue;
            }
        }
        rest.push(arg.to_string());
    }
    rest
}

fn get_parse<T: std::str::FromStr>(cfg: &FlatConfig, key: &str, default: T) -> T {
    cfg.get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Build a [`TrainConfig`] from a flat config (file + overrides merged).
/// Unknown keys under `train.` are ignored; everything has a default.
pub fn train_config(cfg: &FlatConfig) -> TrainConfig {
    let d = TrainConfig::default();
    TrainConfig {
        env: cfg.get("train.env").cloned().unwrap_or(d.env),
        total_steps: get_parse(cfg, "train.total_steps", d.total_steps),
        lr: get_parse(cfg, "train.lr", d.lr),
        ent_coef: get_parse(cfg, "train.ent_coef", d.ent_coef),
        epochs: get_parse(cfg, "train.epochs", d.epochs),
        anneal_lr: get_parse(cfg, "train.anneal_lr", d.anneal_lr),
        seed: get_parse(cfg, "train.seed", d.seed),
        num_workers: get_parse(cfg, "train.num_workers", d.num_workers),
        pool: get_parse(cfg, "train.pool", d.pool),
        run_dir: cfg.get("train.run_dir").cloned(),
        log_every: get_parse(cfg, "train.log_every", d.log_every),
    }
}

/// Load a config file (if given) and apply CLI overrides.
pub fn load(path: Option<&str>, args: &[String]) -> anyhow::Result<(FlatConfig, Vec<String>)> {
    let mut flat = match path {
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .map_err(|e| anyhow::anyhow!("reading config {p}: {e}"))?;
            parse_yaml(&text).map_err(|e| anyhow::anyhow!("parsing {p}: {e}"))?
        }
        None => FlatConfig::new(),
    };
    let rest = apply_overrides(&mut flat, args.iter().map(String::as_str));
    Ok((flat, rest))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overrides_win() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.lr".into(), "0.001".into());
        let rest = apply_overrides(
            &mut cfg,
            ["--train.lr=0.01", "--train.pool=true", "positional"].into_iter(),
        );
        assert_eq!(cfg["train.lr"], "0.01");
        assert_eq!(cfg["train.pool"], "true");
        assert_eq!(rest, vec!["positional"]);
    }

    #[test]
    fn train_config_defaults_and_parsing() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.env".into(), "ocean/memory".into());
        cfg.insert("train.total_steps".into(), "50000".into());
        cfg.insert("train.pool".into(), "true".into());
        let tc = train_config(&cfg);
        assert_eq!(tc.env, "ocean/memory");
        assert_eq!(tc.total_steps, 50_000);
        assert!(tc.pool);
        assert_eq!(tc.epochs, TrainConfig::default().epochs);
    }

    #[test]
    fn bad_values_fall_back_to_default() {
        let mut cfg = FlatConfig::new();
        cfg.insert("train.lr".into(), "banana".into());
        let tc = train_config(&cfg);
        assert_eq!(tc.lr, TrainConfig::default().lr);
    }
}
