//! First-party environments.
//!
//! - [`ocean`] — the paper's §4 sanity suite: each env trains in well under
//!   a minute and is *"trivial with correct implementations and impossible
//!   with specific common bugs"*. Every Ocean env reports a normalized
//!   `score` in `[0, 1]` at episode end; "solved" means score > 0.9.
//! - [`classic`] — full reimplementations of CartPole, a Minigrid-style
//!   gridworld, and a Breakout-style game, used for end-to-end learning.
//! - [`profile`] — workload simulators calibrated to the paper's Table 1
//!   profiles (NetHack, Neural MMO, Pokémon Red, Procgen, Crafter, Atari,
//!   MiniHack, Minigrid): same observation/action structure, step-time
//!   distribution, and reset cost as the real binaries, so the
//!   vectorization experiments exercise the same code paths. See DESIGN.md
//!   §Substitutions.
//!
//! There is deliberately **no registry** (paper §3.2): [`make`] is a plain
//! match over first-party names; downstream users construct their own envs
//! and wrap them with [`PufferEnv`](crate::emulation::PufferEnv) directly
//! (see `examples/custom_env.rs`).

pub mod classic;
pub mod ocean;
pub mod profile;

use crate::emulation::{FlatEnv, PufferEnv, PufferMultiEnv};

/// All first-party env names accepted by [`make`], in display order.
pub const ALL_ENVS: &[&str] = &[
    "ocean/squared",
    "ocean/password",
    "ocean/stochastic",
    "ocean/memory",
    "ocean/multiagent",
    "ocean/spaces",
    "ocean/bandit",
    "classic/cartpole",
    "classic/minigrid",
    "classic/breakout",
    "profile/nethack",
    "profile/minihack",
    "profile/nmmo",
    "profile/pokemon",
    "profile/procgen",
    "profile/atari",
    "profile/crafter",
    "profile/minigrid",
];

/// Ocean env names only (the sanity-suite sweep).
pub const OCEAN_ENVS: &[&str] = &[
    "ocean/squared",
    "ocean/password",
    "ocean/stochastic",
    "ocean/memory",
    "ocean/multiagent",
    "ocean/spaces",
    "ocean/bandit",
];

/// Construct a first-party environment, already wrapped for vectorization.
///
/// `seed` individualizes stochastic env internals (bandit arm layout,
/// profile-sim timing streams); episode randomness comes from the
/// `reset(seed)` calls issued by the vectorizer.
pub fn make(name: &str, seed: u64) -> Box<dyn FlatEnv> {
    match name {
        "ocean/squared" => Box::new(PufferEnv::new(ocean::Squared::new(11, seed))),
        // Password/Bandit hide a *static* secret (paper §4) — it must be
        // the same secret in every vectorized copy or the task is
        // unlearnable, so the instance seed is fixed here.
        "ocean/password" => Box::new(PufferEnv::new(ocean::Password::new(5, 0x50AD))),
        "ocean/stochastic" => Box::new(PufferEnv::new(ocean::Stochastic::new(0.75, 64))),
        "ocean/memory" => Box::new(PufferEnv::new(ocean::Memory::new(3, 0))),
        "ocean/multiagent" => Box::new(PufferMultiEnv::new(ocean::Multiagent::new(8))),
        "ocean/spaces" => Box::new(PufferEnv::new(ocean::SpacesEnv::new(8))),
        "ocean/bandit" => Box::new(PufferEnv::new(ocean::Bandit::new(4, 0xA4A1))),
        "classic/cartpole" => Box::new(PufferEnv::new(classic::CartPole::new(200))),
        "classic/minigrid" => Box::new(PufferEnv::new(classic::MiniGrid::new(7))),
        "classic/breakout" => Box::new(PufferEnv::new(classic::Breakout::new())),
        "profile/nethack" => profile::make_profile("nethack", seed),
        "profile/minihack" => profile::make_profile("minihack", seed),
        "profile/nmmo" => profile::make_profile("nmmo", seed),
        "profile/pokemon" => profile::make_profile("pokemon", seed),
        "profile/procgen" => profile::make_profile("procgen", seed),
        "profile/atari" => profile::make_profile("atari", seed),
        "profile/crafter" => profile::make_profile("crafter", seed),
        "profile/minigrid" => profile::make_profile("minigrid", seed),
        other => panic!(
            "unknown first-party env '{other}'. First-party names: {ALL_ENVS:?}. \
             Custom envs need no registry: wrap them with PufferEnv::new directly."
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_first_party_envs_construct_and_step() {
        for name in ALL_ENVS {
            // Keep profile sims fast in tests by skipping the slowest two.
            if *name == "profile/crafter" || *name == "profile/pokemon" {
                continue;
            }
            let mut env = make(name, 1);
            let rows = env.num_agents();
            let w = env.obs_layout().byte_len();
            let slots = env.action_dims().len();
            let mut obs = vec![0u8; rows * w];
            let mut rewards = vec![0.0; rows];
            let mut terms = vec![false; rows];
            let mut truncs = vec![false; rows];
            env.reset(0, &mut obs);
            let actions = vec![0i32; rows * slots];
            for _ in 0..4 {
                env.step(&actions, &mut obs, &mut rewards, &mut terms, &mut truncs);
            }
        }
    }

    #[test]
    #[should_panic(expected = "unknown first-party env")]
    fn unknown_name_panics_helpfully() {
        make("atari/breakout-v5", 0);
    }
}
