//! The compute-backend layer: one trait, [`PolicyBackend`], between the
//! coordinator (trainer + rollout + policy) and whatever executes the
//! learner math.
//!
//! Two implementations ship:
//!
//! - [`NativeBackend`] (default) — a pure-Rust port of the reference math
//!   in `python/compile/kernels/ref.py` / `gae.py` and `model.py`: the
//!   fused policy-MLP forward, the LSTM cell, the GAE reverse scan, and
//!   the full clipped-surrogate PPO update (hand-derived backprop +
//!   global-norm clip + Adam). Zero native dependencies: the crate builds
//!   and trains on a clean machine with no XLA artifacts and no Python.
//! - `PjrtBackend` (`pjrt` cargo feature) — the original AOT path: JAX/
//!   Pallas entry points lowered to HLO text by `python/compile/aot.py`
//!   and executed through the PJRT C API.
//!
//! Both speak the same flat-parameter contract (the alphabetical
//! `ravel_pytree` order of `model.py`), so checkpoints written against
//! one backend restore against the other **when the spec architectures
//! match** — i.e. feedforward specs; recurrent specs currently train only
//! on the PJRT path, and [`crate::train::Trainer::restore`] rejects
//! mismatched parameter counts. Golden-value parity between the two is
//! pinned by `rust/tests/native_parity.rs` against fixtures generated
//! from the JAX reference (`python/compile/gen_fixtures.py`).

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use crate::runtime::SpecManifest;
use anyhow::Result;

/// Output of a feedforward policy pass over `rows` observations.
#[derive(Clone, Debug, Default)]
pub struct Forward {
    /// `rows × sum(act_dims)` logits, row-major.
    pub logits: Vec<f32>,
    /// `rows` value estimates.
    pub values: Vec<f32>,
}

/// Output of a recurrent (one LSTM cell step) policy pass.
#[derive(Clone, Debug, Default)]
pub struct ForwardLstm {
    pub logits: Vec<f32>,
    pub values: Vec<f32>,
    /// Updated hidden state, `rows × hidden`.
    pub h: Vec<f32>,
    /// Updated cell state, `rows × hidden`.
    pub c: Vec<f32>,
}

/// Flat Adam optimizer state (same length as the parameter vector).
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
}

impl AdamState {
    pub fn new(n_params: usize) -> Self {
        AdamState {
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
            step: 0.0,
        }
    }
}

/// One PPO update's worth of rollout data, time-major `(T, R)` over all
/// agent rows. Feedforward backends flatten to `N = T × R` sample rows;
/// recurrent backends keep the time structure (and the `starts` episode
/// boundaries) for BPTT.
pub struct TrainBatch<'a> {
    /// Rollout segment length `T`.
    pub t: usize,
    /// Total agent rows `R` (`batch_roll`).
    pub r: usize,
    /// `(T, R, obs_dim)` f32.
    pub obs: &'a [f32],
    /// `(T, R)`: 1.0 where the stored obs begins a new episode.
    pub starts: &'a [f32],
    /// `(T, R, slots)` i32.
    pub actions: &'a [i32],
    /// `(T, R)` behavior log-probs.
    pub logp: &'a [f32],
    /// `(T, R)` advantages (from [`PolicyBackend::gae`]).
    pub adv: &'a [f32],
    /// `(T, R)` returns.
    pub ret: &'a [f32],
}

/// The narrow waist between the trainer/policy and the learner math:
/// policy forward, value head, GAE, and the PPO update.
///
/// Parameters travel as one opaque flat f32 vector owned by the caller
/// (the [`Policy`](crate::policy::Policy) / the trainer); backends define
/// its layout via [`PolicyBackend::init_params`] and consume it
/// everywhere else.
pub trait PolicyBackend: Send {
    /// The shape contract this backend was built for.
    fn spec(&self) -> &SpecManifest;

    /// Spec key, e.g. `"ocean_bandit"` (checkpoint compatibility).
    fn key(&self) -> &str;

    /// Produce the initial flat parameter vector (`spec().n_params` long).
    fn init_params(&mut self) -> Result<Vec<f32>>;

    /// Feedforward pass: `obs` is `rows × obs_dim` f32, row-major.
    fn forward(&mut self, params: &[f32], obs: &[f32], rows: usize) -> Result<Forward>;

    /// Recurrent pass: one LSTM cell step with per-row state `h`, `c`
    /// (`rows × hidden` each).
    fn forward_lstm(
        &mut self,
        params: &[f32],
        obs: &[f32],
        h: &[f32],
        c: &[f32],
        rows: usize,
    ) -> Result<ForwardLstm>;

    /// Generalized Advantage Estimation over the `(T, R)` rollout
    /// (`horizon × batch_roll` from the spec). Returns
    /// `(advantages, returns)`, both `(T, R)`.
    fn gae(
        &mut self,
        rewards: &[f32],
        values: &[f32],
        dones: &[f32],
        last_values: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)>;

    /// One clipped-surrogate PPO update, applied in place to `params` and
    /// `opt`. Returns `[loss, pg_loss, v_loss, entropy, approx_kl]`.
    fn train_step(
        &mut self,
        params: &mut Vec<f32>,
        opt: &mut AdamState,
        lr: f32,
        ent_coef: f32,
        batch: &TrainBatch<'_>,
    ) -> Result<[f32; 5]>;
}
