//! [`NativeBackend`] — the default, dependency-free compute backend: a
//! pure-Rust port of the reference math the Pallas kernels are checked
//! against (`python/compile/kernels/ref.py`, `gae.py`) and of the Clean
//! PuffeRL learner in `python/compile/model.py`:
//!
//! - the two-layer tanh policy MLP with actor/critic heads (the fused
//!   `linear_act` kernel's `y = act(x @ w + b)` contract),
//! - the fused-gate LSTM cell (rollout-side recurrence),
//! - the GAE reverse time scan,
//! - the full clipped-surrogate PPO update: hand-derived backprop through
//!   the MLP + softmax heads, global-norm gradient clipping, and Adam —
//!   bit-for-bit the same update rule as `model._adam`.
//!
//! The flat parameter vector uses the same layout as the PJRT path:
//! JAX's `ravel_pytree` flattens the params dict in alphabetical leaf
//! order (`actor.b, actor.w, critic.b, critic.w, enc1.b, enc1.w, enc2.b,
//! enc2.w[, lstm.b, lstm.w]`), so checkpoints are interchangeable across
//! backends for matching (feedforward) architectures. Parity with the
//! JAX reference is pinned by `rust/tests/native_parity.rs` against
//! checked-in fixtures.
//!
//! Recurrent *training* (BPTT through the scan) is not ported yet: specs
//! are synthesized with `lstm: false`, so recurrent envs train with the
//! feedforward policy on the native path; the `pjrt` feature retains full
//! LSTM training.

use super::{AdamState, Forward, ForwardLstm, PolicyBackend, TrainBatch};
use crate::emulation::FlatEnv;
use crate::runtime::{Manifest, SpecManifest};
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::collections::BTreeMap;

// Rollout geometry + hyperparameters, mirroring python/compile/aot.py and
// model.py (the Python↔Rust contract for the PJRT path; the native path
// keeps the same numbers so runs are comparable across backends).
pub const HIDDEN: usize = 128;
pub const B_FWD: usize = 16;
pub const B_ROLL: usize = 32;
pub const HORIZON: usize = 32;
pub const GAMMA: f32 = 0.99;
pub const LAM: f32 = 0.95;

const CLIP: f32 = 0.2;
const VF_COEF: f32 = 0.5;
const MAX_GRAD_NORM: f32 = 0.5;
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Envs whose reference spec (aot.py ENV_SPECS) is recurrent and therefore
/// untrainable on the feedforward-only native backend. Accepts a full
/// [`EnvSpec`](crate::wrappers::EnvSpec) key — wrapper fragments after `+`
/// are ignored. The sweep CLI, examples, and tests use this to route or
/// skip such envs instead of tripping the hard error in
/// [`NativeBackend::for_env`].
pub fn requires_recurrence(env_name: &str) -> bool {
    const RECURRENT_REFERENCE_SPECS: &[&str] = &["ocean/memory"];
    let base_name = env_name.split('+').next().unwrap_or(env_name);
    RECURRENT_REFERENCE_SPECS.contains(&base_name)
}

/// Flat parameter count for the model architecture.
pub fn n_params(obs_dim: usize, act_dims: &[usize], hidden: usize, lstm: bool) -> usize {
    let a: usize = act_dims.iter().sum();
    let h = hidden;
    let mut n = (a + h * a) // actor
        + (1 + h)           // critic
        + (h + obs_dim * h) // enc1
        + (h + h * h); // enc2
    if lstm {
        n += 4 * h + (2 * h) * (4 * h); // fused-gate cell
    }
    n
}

/// Byte offsets of each leaf inside the flat parameter vector, in
/// `ravel_pytree` (alphabetical) order — the single source of truth for
/// the layout, shared by the forward pass (parameter views) and the
/// backward pass (gradient accumulation).
struct ParamRanges {
    actor_b: std::ops::Range<usize>,
    actor_w: std::ops::Range<usize>,
    critic_b: std::ops::Range<usize>,
    critic_w: std::ops::Range<usize>,
    enc1_b: std::ops::Range<usize>,
    enc1_w: std::ops::Range<usize>,
    enc2_b: std::ops::Range<usize>,
    enc2_w: std::ops::Range<usize>,
    lstm_b: std::ops::Range<usize>,
    lstm_w: std::ops::Range<usize>,
}

fn param_ranges(d: usize, h: usize, a: usize, lstm: bool) -> ParamRanges {
    let mut off = 0;
    let mut take = move |n: usize| {
        let r = off..off + n;
        off += n;
        r
    };
    ParamRanges {
        actor_b: take(a),
        actor_w: take(h * a),
        critic_b: take(1),
        critic_w: take(h),
        enc1_b: take(h),
        enc1_w: take(d * h),
        enc2_b: take(h),
        enc2_w: take(h * h),
        lstm_b: if lstm { take(4 * h) } else { 0..0 },
        lstm_w: if lstm { take(2 * h * 4 * h) } else { 0..0 },
    }
}

/// Borrowed views of each leaf inside the flat parameter vector. Weights
/// are row-major `(fan_in, fan_out)`.
struct ParamView<'a> {
    actor_b: &'a [f32],
    actor_w: &'a [f32],
    critic_b: &'a [f32],
    critic_w: &'a [f32],
    enc1_b: &'a [f32],
    enc1_w: &'a [f32],
    enc2_b: &'a [f32],
    enc2_w: &'a [f32],
    lstm_b: &'a [f32],
    lstm_w: &'a [f32],
}

impl<'a> ParamView<'a> {
    fn split(p: &'a [f32], d: usize, h: usize, a: usize, lstm: bool) -> Result<ParamView<'a>> {
        ensure!(
            p.len() == n_params(d, &[a], h, lstm),
            "params len {} != expected {} (obs_dim {d}, act {a}, hidden {h}, lstm {lstm})",
            p.len(),
            n_params(d, &[a], h, lstm)
        );
        let r = param_ranges(d, h, a, lstm);
        Ok(ParamView {
            actor_b: &p[r.actor_b],
            actor_w: &p[r.actor_w],
            critic_b: &p[r.critic_b],
            critic_w: &p[r.critic_w],
            enc1_b: &p[r.enc1_b],
            enc1_w: &p[r.enc1_w],
            enc2_b: &p[r.enc2_b],
            enc2_w: &p[r.enc2_w],
            lstm_b: &p[r.lstm_b],
            lstm_w: &p[r.lstm_w],
        })
    }
}

// ---------------------------------------------------------------------------
// Dense kernels (the ref.py `linear_act_ref` contract, row-major).

/// `out[m×n] = x[m×k] @ w[k×n] + b[n]` (bias broadcast over rows).
fn linear(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let row = &mut out[i * n..(i + 1) * n];
        row.copy_from_slice(b);
        for kk in 0..k {
            let a = x[i * k + kk];
            if a != 0.0 {
                let wrow = &w[kk * n..(kk + 1) * n];
                for (o, &wv) in row.iter_mut().zip(wrow) {
                    *o += a * wv;
                }
            }
        }
    }
}

/// `out[k×n] += a[m×k]ᵀ @ b[m×n]` (weight-gradient GEMM).
fn accum_at_b(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av != 0.0 {
                let brow = &b[i * n..(i + 1) * n];
                let orow = &mut out[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

/// `out[m×k] = a[m×n] @ w[k×n]ᵀ` (input-gradient GEMM).
fn matmul_a_wt(a: &[f32], w: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * k);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        for kk in 0..k {
            let wrow = &w[kk * n..(kk + 1) * n];
            let mut acc = 0.0f32;
            for (&av, &wv) in arow.iter().zip(wrow) {
                acc += av * wv;
            }
            out[i * k + kk] = acc;
        }
    }
}

fn tanh_inplace(xs: &mut [f32]) {
    for x in xs {
        *x = x.tanh();
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

// ---------------------------------------------------------------------------

/// The pure-Rust compute backend (see module docs).
#[derive(Clone)]
pub struct NativeBackend {
    key: String,
    spec: SpecManifest,
    rng: Rng,
}

impl NativeBackend {
    /// Build a backend for a first-party env: probes the emulated
    /// observation layout / action dims and synthesizes the spec with the
    /// shared rollout geometry (`B_FWD`/`B_ROLL`/`HORIZON`).
    ///
    /// `env_name` may be a full [`EnvSpec`](crate::wrappers::EnvSpec) key
    /// ("ocean/squared+clip_reward=1+stack=4"); the wrapper fragments
    /// become part of the backend/checkpoint key, and `env` is expected
    /// to be the *wrapped* probe so the spec is sized from the wrapped
    /// geometry.
    pub fn for_env(env_name: &str, env: &dyn FlatEnv) -> Result<Self> {
        // The native backend trains feedforward only, which cannot solve
        // memory tasks — fail at construction instead of burning the step
        // budget training garbage (this used to be a warning that was
        // trivially lost in training logs).
        ensure!(
            !requires_recurrence(env_name),
            "'{env_name}' needs a recurrent (LSTM) policy to be solvable, but \
             the native backend trains feedforward policies only — training \
             would produce ~chance scores. Build with `--features pjrt`, run \
             `make artifacts`, and select `--backend=pjrt` for LSTM training."
        );
        let agents = env.num_agents();
        ensure!(
            B_ROLL % agents == 0,
            "env '{env_name}': batch_roll {B_ROLL} not divisible by {agents} agents"
        );
        let obs_dim = env.obs_layout().flat_len();
        let act_dims = env.action_dims().to_vec();
        let spec = SpecManifest {
            obs_dim,
            n_params: n_params(obs_dim, &act_dims, HIDDEN, false),
            act_dims,
            agents,
            // Recurrent training is a PJRT-path feature for now; the
            // native policy is always the feedforward MLP.
            lstm: false,
            hidden: HIDDEN,
            batch_fwd: B_FWD,
            batch_roll: B_ROLL,
            horizon: HORIZON,
            gamma: GAMMA as f64,
            lam: LAM as f64,
            params0: String::new(),
            artifacts: BTreeMap::new(),
        };
        let key = Manifest::spec_key_for_env(env_name);
        // Deterministic per-spec init, like aot.py's name-hashed params0.
        let seed = key
            .bytes()
            .fold(0x4E41_5449u64, |acc, b| acc.wrapping_mul(131).wrapping_add(b as u64));
        Ok(NativeBackend::from_spec(key, spec, seed))
    }

    /// Build from an explicit spec (tests, custom geometries).
    pub fn from_spec(key: String, spec: SpecManifest, seed: u64) -> Self {
        NativeBackend {
            key,
            spec,
            rng: Rng::new(seed),
        }
    }

    fn act_sum(&self) -> usize {
        self.spec.act_dims.iter().sum()
    }

    /// Two-layer tanh encoder (model.py `encode`). Returns `(h1, x)`:
    /// `h1` is kept for backprop, `x` feeds the decoder or LSTM cell.
    fn encode(&self, pv: &ParamView<'_>, obs: &[f32], rows: usize) -> (Vec<f32>, Vec<f32>) {
        let (d, h) = (self.spec.obs_dim, self.spec.hidden);
        let mut h1 = vec![0.0; rows * h];
        linear(obs, pv.enc1_w, pv.enc1_b, &mut h1, rows, d, h);
        tanh_inplace(&mut h1);
        let mut x = vec![0.0; rows * h];
        linear(&h1, pv.enc2_w, pv.enc2_b, &mut x, rows, h, h);
        tanh_inplace(&mut x);
        (h1, x)
    }

    /// Actor/critic heads off a hidden state (model.py `decode`).
    fn decode(&self, pv: &ParamView<'_>, hidden: &[f32], rows: usize) -> (Vec<f32>, Vec<f32>) {
        let (h, a) = (self.spec.hidden, self.act_sum());
        let mut logits = vec![0.0; rows * a];
        linear(hidden, pv.actor_w, pv.actor_b, &mut logits, rows, h, a);
        let mut values = vec![0.0; rows];
        linear(hidden, pv.critic_w, pv.critic_b, &mut values, rows, h, 1);
        (logits, values)
    }

    /// Full feedforward pass, returning the intermediate activations
    /// needed for backprop: `(h1, h2, logits, values)`.
    fn forward_cached(
        &self,
        pv: &ParamView<'_>,
        obs: &[f32],
        rows: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let (h1, h2) = self.encode(pv, obs, rows);
        let (logits, values) = self.decode(pv, &h2, rows);
        (h1, h2, logits, values)
    }
}

impl PolicyBackend for NativeBackend {
    fn spec(&self) -> &SpecManifest {
        &self.spec
    }

    fn key(&self) -> &str {
        &self.key
    }

    fn init_params(&mut self) -> Result<Vec<f32>> {
        // CleanRL-style layer_init scaling, as model.init_params: weights
        // are N(0, scale²/fan_in), biases zero, actor head scaled 0.01.
        let (d, h, a) = (self.spec.obs_dim, self.spec.hidden, self.act_sum());
        let lstm = self.spec.lstm;
        let mut p = Vec::with_capacity(self.spec.n_params);
        let dense = |rng: &mut Rng, p: &mut Vec<f32>, fan_in: usize, fan_out: usize, scale: f32| {
            p.extend(std::iter::repeat(0.0).take(fan_out)); // bias
            let s = scale / (fan_in as f32).sqrt();
            p.extend((0..fan_in * fan_out).map(|_| rng.normal() as f32 * s));
        };
        dense(&mut self.rng, &mut p, h, a, 0.01); // actor
        dense(&mut self.rng, &mut p, h, 1, 1.0); // critic
        dense(&mut self.rng, &mut p, d, h, 1.0); // enc1
        dense(&mut self.rng, &mut p, h, h, 1.0); // enc2
        if lstm {
            dense(&mut self.rng, &mut p, 2 * h, 4 * h, 1.0);
        }
        ensure!(
            p.len() == self.spec.n_params,
            "init_params produced {} values, spec says {}",
            p.len(),
            self.spec.n_params
        );
        Ok(p)
    }

    fn forward(&mut self, params: &[f32], obs: &[f32], rows: usize) -> Result<Forward> {
        let (d, h, a) = (self.spec.obs_dim, self.spec.hidden, self.act_sum());
        ensure!(obs.len() == rows * d, "obs len {} != {rows}x{d}", obs.len());
        let pv = ParamView::split(params, d, h, a, self.spec.lstm)?;
        let (_, _, logits, values) = self.forward_cached(&pv, obs, rows);
        Ok(Forward { logits, values })
    }

    fn forward_lstm(
        &mut self,
        params: &[f32],
        obs: &[f32],
        h_in: &[f32],
        c_in: &[f32],
        rows: usize,
    ) -> Result<ForwardLstm> {
        let (d, h, a) = (self.spec.obs_dim, self.spec.hidden, self.act_sum());
        ensure!(obs.len() == rows * d, "obs len {} != {rows}x{d}", obs.len());
        ensure!(h_in.len() == rows * h && c_in.len() == rows * h, "state shape mismatch");
        let pv = ParamView::split(params, d, h, a, true)?;
        let (_h1, x) = self.encode(&pv, obs, rows);

        // fused-gate cell: gates = [x, h] @ w + b, split (i, f, g, o)
        let mut xh = vec![0.0; rows * 2 * h];
        for r in 0..rows {
            xh[r * 2 * h..r * 2 * h + h].copy_from_slice(&x[r * h..(r + 1) * h]);
            xh[r * 2 * h + h..(r + 1) * 2 * h].copy_from_slice(&h_in[r * h..(r + 1) * h]);
        }
        let mut gates = vec![0.0; rows * 4 * h];
        linear(&xh, pv.lstm_w, pv.lstm_b, &mut gates, rows, 2 * h, 4 * h);

        let mut h2 = vec![0.0; rows * h];
        let mut c2 = vec![0.0; rows * h];
        for r in 0..rows {
            let g = &gates[r * 4 * h..(r + 1) * 4 * h];
            for j in 0..h {
                let i_g = sigmoid(g[j]);
                let f_g = sigmoid(g[h + j]);
                let g_g = g[2 * h + j].tanh();
                let o_g = sigmoid(g[3 * h + j]);
                let c = f_g * c_in[r * h + j] + i_g * g_g;
                c2[r * h + j] = c;
                h2[r * h + j] = o_g * c.tanh();
            }
        }

        // decode off the recurrent hidden state
        let (logits, values) = self.decode(&pv, &h2, rows);
        Ok(ForwardLstm {
            logits,
            values,
            h: h2,
            c: c2,
        })
    }

    fn gae(
        &mut self,
        rewards: &[f32],
        values: &[f32],
        dones: &[f32],
        last_values: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        // The ref.py `gae_ref` reverse scan, time-major (T, R).
        let (t_dim, r_dim) = (self.spec.horizon, self.spec.batch_roll);
        let n = t_dim * r_dim;
        ensure!(
            rewards.len() == n && values.len() == n && dones.len() == n,
            "gae inputs must be (T={t_dim}, R={r_dim})"
        );
        ensure!(last_values.len() == r_dim, "last_values must be R={r_dim}");
        let (gamma, lam) = (self.spec.gamma as f32, self.spec.lam as f32);

        let mut adv = vec![0.0f32; n];
        let mut gae = vec![0.0f32; r_dim];
        let mut next_value = last_values.to_vec();
        for t in (0..t_dim).rev() {
            let base = t * r_dim;
            for r in 0..r_dim {
                let mask = 1.0 - dones[base + r];
                let delta = rewards[base + r] + gamma * next_value[r] * mask - values[base + r];
                gae[r] = delta + gamma * lam * mask * gae[r];
                adv[base + r] = gae[r];
                next_value[r] = values[base + r];
            }
        }
        let ret: Vec<f32> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
        Ok((adv, ret))
    }

    fn train_step(
        &mut self,
        params: &mut Vec<f32>,
        opt: &mut AdamState,
        lr: f32,
        ent_coef: f32,
        batch: &TrainBatch<'_>,
    ) -> Result<[f32; 5]> {
        ensure!(
            !self.spec.lstm,
            "NativeBackend does not support recurrent (BPTT) training yet; \
             build with `--features pjrt` for LSTM specs"
        );
        let (d, h, a) = (self.spec.obs_dim, self.spec.hidden, self.act_sum());
        let slots = self.spec.act_dims.len();
        let n = batch.t * batch.r; // feedforward: flatten (T, R) → N rows
        ensure!(batch.obs.len() == n * d, "obs len {} != {n}x{d}", batch.obs.len());
        ensure!(batch.actions.len() == n * slots, "actions len mismatch");
        ensure!(
            batch.logp.len() == n && batch.adv.len() == n && batch.ret.len() == n,
            "logp/adv/ret must be N={n}"
        );
        ensure!(
            opt.m.len() == params.len() && opt.v.len() == params.len(),
            "optimizer state length mismatch"
        );
        let nf = n as f32;

        let pv = ParamView::split(params, d, h, a, false)?;
        let (h1, h2, logits, values) = self.forward_cached(&pv, batch.obs, n);

        // Per-slot softmax statistics: probs, log-probs, slot entropies.
        let mut probs = vec![0.0f32; n * a];
        let mut lps = vec![0.0f32; n * a];
        let mut slot_ent = vec![0.0f32; n * slots];
        let mut logp = vec![0.0f32; n];
        let mut entropy = vec![0.0f32; n];
        for i in 0..n {
            let row = &logits[i * a..(i + 1) * a];
            let mut off = 0;
            for (s, &k) in self.spec.act_dims.iter().enumerate() {
                let seg = &row[off..off + k];
                let mx = seg.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0.0f32;
                for &x in seg {
                    z += (x - mx).exp();
                }
                let logz = z.ln() + mx;
                let mut hs = 0.0f32;
                for (j, &x) in seg.iter().enumerate() {
                    let lp = x - logz;
                    let p = lp.exp();
                    lps[i * a + off + j] = lp;
                    probs[i * a + off + j] = p;
                    hs -= p * lp;
                }
                let act = batch.actions[i * slots + s] as usize;
                ensure!(act < k, "action {act} out of range for slot {s} (dim {k})");
                logp[i] += lps[i * a + off + act];
                slot_ent[i * slots + s] = hs;
                entropy[i] += hs;
                off += k;
            }
        }

        // Clipped-surrogate loss (model._ppo_loss). Advantages are
        // normalized over *this* batch when `batch.norm_adv` — i.e. per
        // minibatch once the trainer splits the segment.
        let (mu, sd) = if batch.norm_adv {
            let mu = batch.adv.iter().sum::<f32>() / nf;
            let var = batch.adv.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / nf;
            (mu, var.sqrt())
        } else {
            (0.0, 1.0)
        };
        let mut pg_loss = 0.0f32;
        let mut v_loss = 0.0f32;
        let mut ent_mean = 0.0f32;
        let mut kl = 0.0f32;
        let mut g_logp = vec![0.0f32; n]; // d pg_loss / d logp_i
        let mut d_value = vec![0.0f32; n];
        for i in 0..n {
            let advn = if batch.norm_adv {
                (batch.adv[i] - mu) / (sd + 1e-8)
            } else {
                batch.adv[i]
            };
            let logratio = logp[i] - batch.logp[i];
            let ratio = logratio.exp();
            let clipped = ratio.clamp(1.0 - CLIP, 1.0 + CLIP);
            let pg1 = -advn * ratio;
            let pg2 = -advn * clipped;
            pg_loss += pg1.max(pg2);
            // max() routes the gradient: the clipped branch is flat
            // outside the trust region. Inside it, clipped == ratio so
            // pg1 == pg2 and this branch covers that case too.
            if pg1 >= pg2 {
                g_logp[i] = -advn * ratio / nf;
            }
            v_loss += 0.5 * (values[i] - batch.ret[i]) * (values[i] - batch.ret[i]);
            d_value[i] = VF_COEF * (values[i] - batch.ret[i]) / nf;
            ent_mean += entropy[i];
            kl += (ratio - 1.0) - logratio;
        }
        pg_loss /= nf;
        v_loss /= nf;
        ent_mean /= nf;
        kl /= nf;
        let loss = pg_loss - ent_coef * ent_mean + VF_COEF * v_loss;

        // d loss / d logits: policy-gradient term + entropy-bonus term.
        let mut d_logits = vec![0.0f32; n * a];
        for i in 0..n {
            let mut off = 0;
            for (s, &k) in self.spec.act_dims.iter().enumerate() {
                let act = batch.actions[i * slots + s] as usize;
                let hs = slot_ent[i * slots + s];
                for j in 0..k {
                    let p = probs[i * a + off + j];
                    let lp = lps[i * a + off + j];
                    let onehot = if j == act { 1.0 } else { 0.0 };
                    d_logits[i * a + off + j] =
                        g_logp[i] * (onehot - p) + (ent_coef / nf) * p * (lp + hs);
                }
                off += k;
            }
        }

        // Backprop through decode + encode into one flat gradient vector
        // (the same `param_ranges` layout the forward pass reads from).
        let mut grads = vec![0.0f32; params.len()];
        {
            let ParamRanges {
                actor_b: r_actor_b,
                actor_w: r_actor_w,
                critic_b: r_critic_b,
                critic_w: r_critic_w,
                enc1_b: r_enc1_b,
                enc1_w: r_enc1_w,
                enc2_b: r_enc2_b,
                enc2_w: r_enc2_w,
                ..
            } = param_ranges(d, h, a, false);

            // Heads.
            for i in 0..n {
                for j in 0..a {
                    grads[r_actor_b.start + j] += d_logits[i * a + j];
                }
                grads[r_critic_b.start] += d_value[i];
            }
            accum_at_b(&h2, &d_logits, &mut grads[r_actor_w.clone()], n, h, a);
            for i in 0..n {
                let dv = d_value[i];
                if dv != 0.0 {
                    for kk in 0..h {
                        grads[r_critic_w.start + kk] += h2[i * h + kk] * dv;
                    }
                }
            }

            // d_h2 = d_logits @ actor_wᵀ + d_value ⊗ critic_w
            let mut d_h2 = vec![0.0f32; n * h];
            matmul_a_wt(&d_logits, pv.actor_w, &mut d_h2, n, a, h);
            for i in 0..n {
                let dv = d_value[i];
                for kk in 0..h {
                    d_h2[i * h + kk] += dv * pv.critic_w[kk];
                }
            }

            // tanh' through enc2.
            let mut d_z2 = d_h2;
            for (dz, &hv) in d_z2.iter_mut().zip(&h2) {
                *dz *= 1.0 - hv * hv;
            }
            accum_at_b(&h1, &d_z2, &mut grads[r_enc2_w.clone()], n, h, h);
            for i in 0..n {
                for j in 0..h {
                    grads[r_enc2_b.start + j] += d_z2[i * h + j];
                }
            }

            // d_h1 = d_z2 @ enc2_wᵀ ; tanh' through enc1.
            let mut d_h1 = vec![0.0f32; n * h];
            matmul_a_wt(&d_z2, pv.enc2_w, &mut d_h1, n, h, h);
            let mut d_z1 = d_h1;
            for (dz, &hv) in d_z1.iter_mut().zip(&h1) {
                *dz *= 1.0 - hv * hv;
            }
            accum_at_b(batch.obs, &d_z1, &mut grads[r_enc1_w.clone()], n, d, h);
            for i in 0..n {
                for j in 0..h {
                    grads[r_enc1_b.start + j] += d_z1[i * h + j];
                }
            }
        }

        // Global-norm clip + Adam (model._adam, flat).
        let gnorm = (grads.iter().map(|g| g * g).sum::<f32>() + 1e-12).sqrt();
        let scale = (MAX_GRAD_NORM / gnorm).min(1.0);
        opt.step += 1.0;
        let bc1 = 1.0 - ADAM_B1.powf(opt.step);
        let bc2 = 1.0 - ADAM_B2.powf(opt.step);
        for i in 0..params.len() {
            let g = grads[i] * scale;
            opt.m[i] = ADAM_B1 * opt.m[i] + (1.0 - ADAM_B1) * g;
            opt.v[i] = ADAM_B2 * opt.v[i] + (1.0 - ADAM_B2) * g * g;
            let mhat = opt.m[i] / bc1;
            let vhat = opt.v[i] / bc2;
            params[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
        }

        Ok([loss, pg_loss, v_loss, ent_mean, kl])
    }

    fn fork_for_rollout(&self) -> Result<Box<dyn PolicyBackend>> {
        // The backend is pure math over caller-owned parameters; its only
        // state (the init RNG) is never touched by forward passes, so a
        // plain clone is a safe concurrent-inference fork.
        Ok(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(d: usize, act_dims: Vec<usize>, hidden: usize) -> SpecManifest {
        SpecManifest {
            obs_dim: d,
            n_params: n_params(d, &act_dims, hidden, false),
            act_dims,
            agents: 1,
            lstm: false,
            hidden,
            batch_fwd: 4,
            batch_roll: 4,
            horizon: 3,
            gamma: 0.99,
            lam: 0.95,
            params0: String::new(),
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn init_params_matches_spec_len() {
        let mut b = NativeBackend::from_spec("t".into(), tiny_spec(5, vec![3, 2], 8), 1);
        let p = b.init_params().unwrap();
        assert_eq!(p.len(), b.spec().n_params);
        // Actor bias and all biases start at zero; some weights nonzero.
        assert!(p[..5].iter().all(|&x| x == 0.0), "actor bias zero-init");
        assert!(p.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let mut b = NativeBackend::from_spec("t".into(), tiny_spec(5, vec![3, 2], 8), 2);
        let p = b.init_params().unwrap();
        let obs: Vec<f32> = (0..4 * 5).map(|i| (i as f32 * 0.37).sin()).collect();
        let out = b.forward(&p, &obs, 4).unwrap();
        assert_eq!(out.logits.len(), 4 * 5);
        assert_eq!(out.values.len(), 4);
        assert!(out.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn gae_single_row_hand_check() {
        // T=3, R=1, gamma/lam as spec; verify against a hand-unrolled scan.
        let mut spec = tiny_spec(1, vec![2], 4);
        spec.horizon = 3;
        spec.batch_roll = 1;
        let mut b = NativeBackend::from_spec("t".into(), spec, 3);
        let rewards = [1.0f32, 0.0, 2.0];
        let values = [0.5f32, 0.4, 0.3];
        let dones = [0.0f32, 1.0, 0.0];
        let last = [0.7f32];
        let (adv, ret) = b.gae(&rewards, &values, &dones, &last).unwrap();
        let (g, l) = (0.99f32, 0.95f32);
        let d2 = 2.0 + g * 0.7 - 0.3;
        let a2 = d2;
        let d1 = 0.0 + 0.0 - 0.4; // done masks the bootstrap
        let a1 = d1;
        let d0 = 1.0 + g * 0.4 - 0.5;
        let a0 = d0 + g * l * a1;
        assert!((adv[0] - a0).abs() < 1e-6, "{} vs {a0}", adv[0]);
        assert!((adv[1] - a1).abs() < 1e-6);
        assert!((adv[2] - a2).abs() < 1e-6);
        assert!((ret[2] - (a2 + 0.3)).abs() < 1e-6);
    }

    #[test]
    fn train_step_descends_on_value_loss() {
        // With adv ≡ 0 the update is pure value regression: repeated steps
        // must reduce v_loss.
        let mut b = NativeBackend::from_spec("t".into(), tiny_spec(3, vec![2], 8), 4);
        let mut params = b.init_params().unwrap();
        let mut opt = AdamState::new(params.len());
        let t = 3usize;
        let r = 4usize;
        let n = t * r;
        let obs: Vec<f32> = (0..n * 3).map(|i| ((i * 7 % 13) as f32) / 13.0).collect();
        let actions = vec![0i32; n];
        let logp = vec![-0.69f32; n];
        let adv = vec![0.0f32; n];
        let ret: Vec<f32> = (0..n).map(|i| (i % 3) as f32).collect();
        let starts = vec![0.0; n];
        let batch = TrainBatch {
            t,
            r,
            norm_adv: true,
            obs: &obs,
            starts: &starts,
            actions: &actions,
            logp: &logp,
            adv: &adv,
            ret: &ret,
        };
        let first = b.train_step(&mut params, &mut opt, 0.05, 0.0, &batch).unwrap();
        let mut last = first;
        for _ in 0..60 {
            last = b.train_step(&mut params, &mut opt, 0.05, 0.0, &batch).unwrap();
        }
        assert!(
            last[2] < first[2] * 0.5,
            "v_loss did not descend: {} -> {}",
            first[2],
            last[2]
        );
        assert_eq!(opt.step, 61.0);
    }

    #[test]
    fn recurrent_reference_env_is_a_hard_error() {
        let env = crate::envs::make("ocean/memory", 0);
        let err = NativeBackend::for_env("ocean/memory", env.as_ref())
            .err()
            .expect("recurrent env must not construct on the native backend")
            .to_string();
        assert!(err.contains("--features pjrt"), "unactionable error: {err}");
        assert!(err.contains("--backend=pjrt"), "unactionable error: {err}");
        // Wrapper fragments in the spec key don't mask the base env.
        assert!(NativeBackend::for_env("ocean/memory+stack=4", env.as_ref()).is_err());
        assert!(requires_recurrence("ocean/memory+clip_reward=1"));
        assert!(!requires_recurrence("ocean/bandit"));
    }

    #[test]
    fn norm_adv_off_feeds_raw_advantages() {
        // Constant positive advantages: normalized they collapse to zero
        // (zero policy gradient); raw they drive an actor update. The two
        // settings must therefore diverge from the same start.
        let mk = || NativeBackend::from_spec("t".into(), tiny_spec(3, vec![2], 8), 9);
        let mut b = mk();
        let params0 = b.init_params().unwrap();
        let t = 3usize;
        let r = 4usize;
        let n = t * r;
        let obs: Vec<f32> = (0..n * 3).map(|i| ((i * 5 % 11) as f32) / 11.0).collect();
        let actions = vec![1i32; n];
        let logp = vec![-0.69f32; n];
        let adv = vec![1.0f32; n];
        let ret = vec![0.0f32; n];
        let starts = vec![0.0f32; n];
        let run = |norm_adv: bool| {
            let mut b = mk();
            let mut params = params0.clone();
            let mut opt = AdamState::new(params.len());
            let batch = TrainBatch {
                t,
                r,
                norm_adv,
                obs: &obs,
                starts: &starts,
                actions: &actions,
                logp: &logp,
                adv: &adv,
                ret: &ret,
            };
            let m = b.train_step(&mut params, &mut opt, 0.01, 0.0, &batch).unwrap();
            (params, m)
        };
        let (p_norm, m_norm) = run(true);
        let (p_raw, m_raw) = run(false);
        assert!((m_norm[1]).abs() < 1e-6, "normalized constant adv → pg 0");
        assert!(m_raw[1].abs() > 1e-3, "raw adv must drive the surrogate");
        assert_ne!(p_norm, p_raw);
    }

    #[test]
    fn fork_for_rollout_matches_forward() {
        let mut b = NativeBackend::from_spec("t".into(), tiny_spec(5, vec![3], 8), 2);
        let p = b.init_params().unwrap();
        let obs: Vec<f32> = (0..4 * 5).map(|i| (i as f32 * 0.31).cos()).collect();
        let mut fork = b.fork_for_rollout().unwrap();
        assert_eq!(fork.key(), b.key());
        let a = b.forward(&p, &obs, 4).unwrap();
        let f = fork.forward(&p, &obs, 4).unwrap();
        assert_eq!(a.logits, f.logits);
        assert_eq!(a.values, f.values);
    }
}
