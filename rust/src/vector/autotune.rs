//! The autotune utility (paper §3.3): *"Obtaining the best configuration
//! for your environment and hardware requires testing all four code paths.
//! We provide an utility that benchmarks valid vectorization settings."*

use super::{Multiprocessing, Serial, VecConfig, VecEnv};
use crate::util::timer::Timer;
use crate::wrappers::EnvSpec;
use anyhow::Result;

/// Result of benchmarking one candidate configuration.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub label: String,
    pub cfg: VecConfig,
    /// Aggregate environment steps per second (env-steps, not agent-steps).
    pub sps: f64,
}

/// Benchmark every valid backend/code-path combination for `duration`
/// seconds each and return results sorted best-first.
///
/// The candidate env (including any wrapper chain — tuning with the
/// exact pipeline you will train with matters, since e.g. stacking
/// changes the bytes moved per step) is described by an [`EnvSpec`].
/// `num_envs` is the env budget; worker counts and batch sizes are swept
/// over the divisors that produce each of the four code paths plus the
/// serial baseline.
pub fn autotune(
    spec: &EnvSpec,
    num_envs: usize,
    max_workers: usize,
    duration_secs: f64,
) -> Result<Vec<TuneResult>> {
    let mut results = Vec::new();

    // Serial reference.
    {
        let cfg = VecConfig {
            num_envs,
            num_workers: 1,
            batch_size: num_envs,
            ..Default::default()
        };
        let v = Serial::from_spec(spec, cfg.clone())?;
        let sps = measure(v, duration_secs)?;
        results.push(TuneResult {
            label: "serial".into(),
            cfg,
            sps,
        });
    }

    let worker_counts: Vec<usize> = [1, 2, 4, 8, 16]
        .into_iter()
        .filter(|&w| w <= max_workers && w <= num_envs && num_envs % w == 0)
        .collect();

    for &workers in &worker_counts {
        let epw = num_envs / workers;
        // Candidate (batch, zero_copy, label) per code path.
        let mut candidates: Vec<(usize, bool, String)> =
            vec![(num_envs, false, format!("sync w={workers}"))];
        if workers > 1 {
            candidates.push((epw, false, format!("pool-single w={workers}")));
            if num_envs / 2 >= epw && (num_envs / 2) % epw == 0 && num_envs / 2 != epw {
                candidates.push((num_envs / 2, false, format!("pool-half w={workers}")));
                candidates.push((num_envs / 2, true, format!("zero-copy-half w={workers}")));
            }
        }
        for (batch, zero_copy, label) in candidates {
            let cfg = VecConfig {
                num_envs,
                num_workers: workers,
                batch_size: batch,
                zero_copy,
                ..Default::default()
            };
            if cfg.mode().is_err() {
                continue;
            }
            let v = Multiprocessing::from_spec(spec, cfg.clone())?;
            let sps = measure(v, duration_secs)?;
            results.push(TuneResult { label, cfg, sps });
        }
    }

    results.sort_by(|a, b| b.sps.partial_cmp(&a.sps).unwrap());
    Ok(results)
}

/// Drive a backend with no-op actions for `secs`, returning env-steps/sec.
pub fn measure<V: VecEnv>(mut v: V, secs: f64) -> Result<f64> {
    let slots = v.action_dims().len();
    let rows = v.batch_rows();
    let batch_envs = v.batch_size();
    let actions = vec![0i32; rows * slots];
    v.async_reset(0);
    // Warmup.
    for _ in 0..3 {
        let _ = v.recv()?;
        v.send(&actions)?;
    }
    let t = Timer::start();
    let mut steps = 0u64;
    while t.secs() < secs {
        let _ = v.recv()?;
        v.send(&actions)?;
        steps += batch_envs as u64;
    }
    Ok(steps as f64 / t.secs())
}

/// Pretty-print tune results as an aligned table.
pub fn format_results(results: &[TuneResult]) -> String {
    let mut out = String::from(
        "rank  config                    workers  batch  zero_copy        SPS\n",
    );
    for (i, r) in results.iter().enumerate() {
        out.push_str(&format!(
            "{:>4}  {:<24}  {:>7}  {:>5}  {:>9}  {:>9.0}\n",
            i + 1,
            r.label,
            r.cfg.num_workers,
            r.cfg.batch_size,
            r.cfg.zero_copy,
            r.sps
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs;

    #[test]
    fn autotune_covers_code_paths_and_ranks() {
        let spec = EnvSpec::new("ocean/squared");
        let results = autotune(&spec, 4, 2, 0.05).unwrap();
        assert!(results.len() >= 3, "too few candidates: {results:?}");
        // Sorted best-first.
        for pair in results.windows(2) {
            assert!(pair[0].sps >= pair[1].sps);
        }
        // Serial is always among the candidates.
        assert!(results.iter().any(|r| r.label == "serial"));
        let table = format_results(&results);
        assert!(table.contains("serial"));
    }
}
