//! Shared-memory slabs and the per-worker signaling flags.
//!
//! The multiprocessing backend exchanges *all* per-step data
//! (observations, rewards, terminals, truncateds, actions) through large
//! preallocated shared arrays, and signals readiness through per-worker
//! atomic flags that both sides busy-wait on — the paper's "shared memory
//! for data communication" + "shared flags for signaling" design, which
//! reduces steady-state inter-process communication to zero. Only infos
//! travel over a channel (the paper's pipes), and only when non-empty.
//!
//! ## Safety protocol
//!
//! Each worker owns a disjoint region of every slab. Region access
//! alternates strictly between leader and worker, mediated by that
//! worker's [`Flag`]:
//!
//! ```text
//!   leader writes actions ──Release──▶ ACTIONS_READY
//!   worker Acquire-loads, steps envs, writes obs/rew/term/trunc
//!          ──Release──▶ OBS_READY
//!   leader Acquire-loads, reads results, (claims), writes next actions…
//! ```
//!
//! The Release/Acquire pair on the flag makes every slab write by one side
//! visible to the other before it touches the region, so the raw slices
//! handed out by [`Slab`] are never accessed concurrently.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// Worker flag states.
pub const IDLE: u32 = 0;
/// Leader → worker: actions for your envs are in the action slab; step.
pub const ACTIONS_READY: u32 = 1;
/// Worker → leader: observations/rewards/terms are in the slabs.
pub const OBS_READY: u32 = 2;
/// Leader → worker: reset all your envs (seed in the seed slab).
pub const RESET: u32 = 3;
/// Leader has taken this worker's OBS_READY output (pool bookkeeping).
pub const CLAIMED: u32 = 4;
/// Leader → worker: exit.
pub const SHUTDOWN: u32 = 5;
/// Worker → leader: an env panicked; the backend is dead.
pub const POISONED: u32 = 6;

/// A fixed-size shared array of `T` carved into per-worker regions.
///
/// Interior mutability + manual synchronization: see the module docs for
/// the flag protocol that makes region access exclusive.
pub struct Slab<T> {
    data: Box<[UnsafeCell<T>]>,
}

// SAFETY: access to disjoint regions is serialized by the flag protocol;
// UnsafeCell<T> has T's layout.
unsafe impl<T: Send> Send for Slab<T> {}
unsafe impl<T: Send> Sync for Slab<T> {}

impl<T: Copy + Default> Slab<T> {
    pub fn new(len: usize) -> Arc<Self> {
        let data: Box<[UnsafeCell<T>]> = (0..len).map(|_| UnsafeCell::new(T::default())).collect();
        Arc::new(Slab { data })
    }

    #[allow(dead_code)]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[allow(dead_code)]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Borrow a region immutably.
    ///
    /// # Safety
    /// The caller must hold the flag state that grants it the region, and
    /// the range must stay within its region.
    #[inline]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &[T] {
        debug_assert!(start + len <= self.data.len());
        std::slice::from_raw_parts(self.data.as_ptr().add(start) as *const T, len)
    }

    /// Borrow a region mutably.
    ///
    /// # Safety
    /// As [`slice`](Self::slice), plus exclusivity: no other live
    /// reference to the range (guaranteed by the flag protocol).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.data.len());
        std::slice::from_raw_parts_mut(self.data.as_ptr().add(start) as *mut T, len)
    }
}

/// One worker's signaling flag.
pub struct Flag {
    state: AtomicU32,
}

impl Flag {
    pub fn new() -> Self {
        Flag {
            state: AtomicU32::new(IDLE),
        }
    }

    #[inline]
    pub fn load(&self) -> u32 {
        self.state.load(Ordering::Acquire)
    }

    #[inline]
    pub fn store(&self, v: u32) {
        self.state.store(v, Ordering::Release);
    }

    /// CAS used by the pool leader to claim an OBS_READY worker exactly
    /// once.
    #[inline]
    pub fn try_claim(&self) -> bool {
        self.state
            .compare_exchange(OBS_READY, CLAIMED, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Busy-wait until the flag matches `pred`, spinning `spin_budget`
    /// iterations between yields. Returns the matched state.
    #[inline]
    pub fn wait(&self, spin_budget: u32, pred: impl Fn(u32) -> bool) -> u32 {
        loop {
            for _ in 0..spin_budget.max(1) {
                let s = self.load();
                if pred(s) {
                    return s;
                }
                std::hint::spin_loop();
            }
            // Oversubscribed or long step: give the core away. On the
            // paper's many-core desktop this branch is cold; on small
            // hosts it is what keeps busy-wait from starving the workers.
            std::thread::yield_now();
        }
    }
}

impl Default for Flag {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn slab_regions_round_trip() {
        let slab = Slab::<f32>::new(8);
        unsafe {
            slab.slice_mut(2, 3).copy_from_slice(&[1.0, 2.0, 3.0]);
            assert_eq!(slab.slice(2, 3), &[1.0, 2.0, 3.0]);
            assert_eq!(slab.slice(0, 2), &[0.0, 0.0]);
        }
    }

    #[test]
    fn flag_claim_is_exclusive() {
        let f = Flag::new();
        f.store(OBS_READY);
        assert!(f.try_claim());
        assert!(!f.try_claim(), "double claim must fail");
        assert_eq!(f.load(), CLAIMED);
    }

    #[test]
    fn flag_protocol_passes_data_across_threads() {
        let slab = Slab::<u32>::new(4);
        let flag = Arc::new(Flag::new());
        let (s2, f2) = (slab.clone(), flag.clone());
        let t = thread::spawn(move || {
            f2.wait(16, |s| s == ACTIONS_READY);
            let val = unsafe { s2.slice(0, 1) }[0];
            unsafe {
                s2.slice_mut(1, 1)[0] = val * 2;
            }
            f2.store(OBS_READY);
        });
        unsafe {
            slab.slice_mut(0, 1)[0] = 21;
        }
        flag.store(ACTIONS_READY);
        flag.wait(16, |s| s == OBS_READY);
        assert_eq!(unsafe { slab.slice(1, 1) }[0], 42);
        t.join().unwrap();
    }

    #[test]
    fn wait_matches_any_predicate() {
        let f = Flag::new();
        f.store(SHUTDOWN);
        let s = f.wait(4, |s| s == ACTIONS_READY || s == SHUTDOWN);
        assert_eq!(s, SHUTDOWN);
    }
}
