//! `puffer` — the Clean PuffeRL runner CLI (paper §6: "a runner file with
//! a CLI for all included PufferLib environments, clean YAML configs").
//!
//! ```text
//! puffer train <env> [--config cfg.yaml] [--train.lr=3e-3] [--backend=native|pjrt] ...
//! puffer eval <env> --checkpoint runs/x/checkpoint.bin [--episodes 20]
//! puffer sweep                      # train the whole Ocean suite
//! puffer autotune <env> [--envs 8] [--workers 4] [--secs 1.0]
//! puffer envs                       # list first-party environments
//! ```
//!
//! The default backend is the pure-Rust `NativeBackend` (no artifacts, no
//! Python). `--backend=pjrt` selects the AOT/PJRT path; it requires a
//! build with `--features pjrt` plus `make artifacts`.

use anyhow::{Context, Result};
use pufferlib::config;
use pufferlib::envs;
use pufferlib::train::{Checkpoint, TrainConfig, Trainer};
use pufferlib::vector::autotune;
use std::sync::Arc;

#[cfg(feature = "pjrt")]
const ARTIFACTS: &str = "artifacts";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest: Vec<String> = args.iter().skip(1).cloned().collect();

    match cmd {
        "train" => cmd_train(&rest),
        "eval" => cmd_eval(&rest),
        "sweep" => cmd_sweep(&rest),
        "autotune" => cmd_autotune(&rest),
        "envs" => {
            for name in envs::ALL_ENVS {
                println!("{name}");
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown command '{other}'");
        }
    }
}

fn print_help() {
    println!(
        "puffer — PufferLib (Rust + JAX + Pallas) runner\n\n\
         USAGE:\n  puffer train <env> [--config FILE] [--train.KEY=VAL ...] [--backend=native|pjrt]\n  \
         puffer eval <env> --checkpoint=FILE [--episodes=N]\n  \
         puffer sweep [--train.KEY=VAL ...]        train the whole Ocean suite\n  \
         puffer autotune <env> [--envs=N] [--workers=W] [--secs=S]\n  \
         puffer envs                               list first-party envs\n\n\
         Train keys: env total_steps lr ent_coef epochs anneal_lr seed\n\
         \x20           num_workers pool run_dir log_every\n\n\
         Backends: native (default, pure Rust) | pjrt (AOT artifacts;\n\
         \x20         needs a build with --features pjrt and `make artifacts`)"
    );
}

/// Extract `--config FILE` and positional args, leaving `--k=v` overrides.
fn split_args(args: &[String]) -> (Option<String>, Vec<String>, Vec<String>) {
    let mut cfg_file = None;
    let mut positional = Vec::new();
    let mut overrides = Vec::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if a == "--config" {
            cfg_file = it.next().cloned();
        } else if a.starts_with("--") {
            overrides.push(a.clone());
        } else {
            positional.push(a.clone());
        }
    }
    (cfg_file, positional, overrides)
}

/// Pull `--backend=...` out of the override list (default: native).
fn take_backend(overrides: &mut Vec<String>) -> String {
    let mut backend = "native".to_string();
    overrides.retain(|a| {
        if let Some(v) = a.strip_prefix("--backend=") {
            backend = v.to_string();
            false
        } else {
            true
        }
    });
    backend
}

fn make_trainer(tc: TrainConfig, backend: &str) -> Result<Trainer> {
    match backend {
        "native" => Trainer::native(tc),
        "pjrt" => pjrt_trainer(tc),
        other => anyhow::bail!("unknown backend '{other}' (expected native or pjrt)"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_trainer(tc: TrainConfig) -> Result<Trainer> {
    Trainer::pjrt(tc, ARTIFACTS)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_trainer(_tc: TrainConfig) -> Result<Trainer> {
    anyhow::bail!(
        "this binary was built without the `pjrt` feature; rebuild with \
         `cargo build --release --features pjrt` and run `make artifacts`"
    )
}

fn cmd_train(args: &[String]) -> Result<()> {
    let (cfg_file, positional, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
    if let Some(env) = positional.first() {
        flat.insert("train.env".into(), env.clone());
    }
    let tc = config::train_config(&flat);
    println!(
        "training {} for {} steps ({backend} backend) ...",
        tc.env, tc.total_steps
    );
    let mut trainer = make_trainer(tc, &backend)?;
    let report = trainer.train()?;
    println!(
        "done: {} steps @ {:.0} SPS, {} episodes, score {}, return {}",
        report.global_step,
        report.sps,
        report.episodes,
        report
            .mean_score
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
        report
            .mean_return
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
    );
    Ok(())
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let (cfg_file, positional, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    // Pull out eval-specific flags.
    let mut checkpoint = None;
    let mut episodes = 20usize;
    overrides.retain(|a| {
        if let Some(v) = a.strip_prefix("--checkpoint=") {
            checkpoint = Some(v.to_string());
            false
        } else if let Some(v) = a.strip_prefix("--episodes=") {
            episodes = v.parse().unwrap_or(20);
            false
        } else {
            true
        }
    });
    let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
    if let Some(env) = positional.first() {
        flat.insert("train.env".into(), env.clone());
    }
    let tc = config::train_config(&flat);
    let mut trainer = make_trainer(tc, &backend)?;
    if let Some(ck_path) = checkpoint {
        let ck = Checkpoint::load(&ck_path).context("loading checkpoint")?;
        trainer.restore(&ck)?;
        println!("restored checkpoint at step {}", ck.global_step);
    }
    let report = trainer.eval(episodes)?;
    println!(
        "eval: {} episodes, score {}, return {}",
        report.episodes,
        report
            .mean_score
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
        report
            .mean_return
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
    );
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let (cfg_file, _, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    let mut solved = 0;
    for env in envs::OCEAN_ENVS {
        let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
        flat.insert("train.env".into(), env.to_string());
        let tc = config::train_config(&flat);
        let mut trainer = make_trainer(tc, &backend)?;
        let report = trainer.train()?;
        let score = report.mean_score.unwrap_or(0.0);
        let ok = score > 0.9;
        if ok {
            solved += 1;
        }
        println!(
            "{:<20} score {:.3}  {}",
            env,
            score,
            if ok { "SOLVED" } else { "unsolved" }
        );
    }
    println!("{solved}/{} Ocean envs solved", envs::OCEAN_ENVS.len());
    Ok(())
}

fn cmd_autotune(args: &[String]) -> Result<()> {
    let (_, positional, overrides) = split_args(args);
    let env = positional
        .first()
        .cloned()
        .unwrap_or_else(|| "ocean/squared".into());
    let mut num_envs = 8;
    let mut workers = 4;
    let mut secs = 1.0f64;
    for a in &overrides {
        if let Some(v) = a.strip_prefix("--envs=") {
            num_envs = v.parse().unwrap_or(8);
        } else if let Some(v) = a.strip_prefix("--workers=") {
            workers = v.parse().unwrap_or(4);
        } else if let Some(v) = a.strip_prefix("--secs=") {
            secs = v.parse().unwrap_or(1.0);
        }
    }
    println!("autotuning {env} with {num_envs} envs (≤{workers} workers, {secs}s per config) ...");
    let env_name = env.clone();
    let factory: Arc<dyn Fn(usize) -> Box<dyn pufferlib::emulation::FlatEnv> + Send + Sync> =
        Arc::new(move |i| envs::make(&env_name, i as u64));
    let results = autotune::autotune(factory, num_envs, workers, secs)?;
    print!("{}", autotune::format_results(&results));
    println!(
        "\nrecommended: {} (num_workers={}, batch_size={}, zero_copy={})",
        results[0].label,
        results[0].cfg.num_workers,
        results[0].cfg.batch_size,
        results[0].cfg.zero_copy
    );
    Ok(())
}
