//! `puffer` — the Clean PuffeRL runner CLI (paper §6: "a runner file with
//! a CLI for all included PufferLib environments, clean YAML configs").
//!
//! ```text
//! puffer train <env> [--config cfg.yaml] [--train.lr=3e-3] [--wrap.stack=4] [--policy.lstm=true] ...
//! puffer eval <env> --checkpoint runs/x/checkpoint.bin [--episodes 20]
//! puffer sweep                      # train the whole Ocean suite
//! puffer autotune <env> [--envs 8] [--workers 4] [--secs 1.0] [--wrap.* ...]
//! puffer policy describe <env> [--wrap.* ...] [--policy.* ...]
//! puffer envs                       # list first-party environments
//! ```
//!
//! `--wrap.*` overrides compose the one-line wrapper pipeline onto the
//! env (innermost first: action_repeat, time_limit, scale_reward,
//! clip_reward, normalize_obs, stack), e.g.
//! `puffer train ocean/squared --wrap.clip_reward=1.0 --wrap.stack=4`.
//!
//! `--policy.*` overrides compose the policy architecture (per-leaf
//! encoders × recurrence × action head): `--policy.hidden=64`
//! `--policy.lstm=true` `--policy.embed_dim=8`. Recurrent reference envs
//! (e.g. `ocean/memory`) default to the LSTM sandwich and train natively;
//! `puffer policy describe <env>` prints the resolved stages and param
//! counts for debugging spec/env mismatches.
//!
//! The default backend is the pure-Rust `NativeBackend` (no artifacts, no
//! Python). `--backend=pjrt` selects the AOT/PJRT path; it requires a
//! build with `--features pjrt` plus `make artifacts`.

use anyhow::{Context, Result};
use pufferlib::config;
use pufferlib::envs;
use pufferlib::train::{Checkpoint, TrainConfig, Trainer};
use pufferlib::vector::autotune;
use pufferlib::wrappers::EnvSpec;

#[cfg(feature = "pjrt")]
const ARTIFACTS: &str = "artifacts";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest: Vec<String> = args.iter().skip(1).cloned().collect();

    match cmd {
        "train" => cmd_train(&rest),
        "eval" => cmd_eval(&rest),
        "sweep" => cmd_sweep(&rest),
        "autotune" => cmd_autotune(&rest),
        "policy" => cmd_policy(&rest),
        "envs" => {
            for name in envs::ALL_ENVS {
                println!("{name}");
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown command '{other}'");
        }
    }
}

fn print_help() {
    println!(
        "puffer — PufferLib (Rust + JAX + Pallas) runner\n\n\
         USAGE:\n  puffer train <env> [--config FILE] [--train.KEY=VAL ...] [--wrap.KEY=VAL ...] [--policy.KEY=VAL ...] [--pipeline.KEY=VAL ...] [--backend=native|pjrt]\n  \
         puffer eval <env> --checkpoint=FILE [--episodes=N]\n  \
         puffer sweep [--train.KEY=VAL ...]        train the whole Ocean suite\n  \
         puffer autotune <env> [--envs=N] [--workers=W] [--secs=S] [--wrap.KEY=VAL ...]\n  \
         puffer policy describe <env> [--wrap.KEY=VAL ...] [--policy.KEY=VAL ...]\n  \
         puffer envs                               list first-party envs\n\n\
         Train keys: env total_steps lr ent_coef epochs minibatches norm_adv\n\
         \x20           anneal_lr seed num_workers pool run_dir log_every\n\
         Pipeline keys: depth — 0 (default) trains serially; d >= 1 runs an\n\
         \x20 overlapped collector/learner pipeline, the collector filling up\n\
         \x20 to d rollout segments ahead (e.g. --pipeline.depth=1 with\n\
         \x20 --train.pool=true --train.minibatches=4 for max overlap)\n\
         Wrap keys (one-line wrapper pipeline, applied innermost-first in\n\
         \x20 this order): action_repeat time_limit scale_reward clip_reward\n\
         \x20 normalize_obs stack — e.g. --wrap.clip_reward=1.0 --wrap.stack=4\n\
         Policy keys (architecture = per-leaf encoders x recurrence x head):\n\
         \x20 hidden (trunk width) | lstm true/false | lstm_hidden (state\n\
         \x20 width) | embed_dim (token-leaf embedding tables, 0 = raw) |\n\
         \x20 head categorical|quantized:<bins> — recurrent reference envs\n\
         \x20 (ocean/memory) default to lstm=true and train natively; a\n\
         \x20 non-default spec becomes part of the checkpoint key\n\n\
         Backends: native (default, pure Rust; any --policy.* spec) | pjrt\n\
         \x20         (AOT artifacts, default archs only; needs a build with\n\
         \x20         --features pjrt and `make artifacts`)"
    );
}

/// Extract `--config FILE` and positional args, leaving `--k=v` overrides.
fn split_args(args: &[String]) -> (Option<String>, Vec<String>, Vec<String>) {
    let mut cfg_file = None;
    let mut positional = Vec::new();
    let mut overrides = Vec::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if a == "--config" {
            cfg_file = it.next().cloned();
        } else if a.starts_with("--") {
            overrides.push(a.clone());
        } else {
            positional.push(a.clone());
        }
    }
    (cfg_file, positional, overrides)
}

/// Reject `--key=value` overrides outside the namespaces this command
/// owns. Without this, a typo'd `--clip_reward=1` (missing the `wrap.`
/// prefix) or `--trian.lr=3e-3` would be silently ignored — the same
/// footgun the strict config parser closes for key *suffixes*.
fn reject_stray_overrides(overrides: &[String], allowed: &[&str]) -> Result<()> {
    for a in overrides {
        if let Some(body) = a.strip_prefix("--") {
            let key = body.split('=').next().unwrap_or(body);
            if !allowed.iter().any(|ns| key.starts_with(ns)) {
                let expected: Vec<String> = allowed.iter().map(|ns| format!("--{ns}KEY=VAL")).collect();
                anyhow::bail!(
                    "unrecognized flag '--{key}...': this command accepts {}",
                    expected.join(" and ")
                );
            }
            // Space-separated values (`--wrap.stack 4`) would otherwise
            // be dropped without effect by the override parser.
            anyhow::ensure!(
                body.contains('='),
                "flag '--{key}' is missing a value: use --{key}=VALUE"
            );
        }
    }
    Ok(())
}

/// Pull `--backend=...` out of the override list (default: native).
fn take_backend(overrides: &mut Vec<String>) -> String {
    let mut backend = "native".to_string();
    overrides.retain(|a| {
        if let Some(v) = a.strip_prefix("--backend=") {
            backend = v.to_string();
            false
        } else {
            true
        }
    });
    backend
}

fn make_trainer(tc: TrainConfig, backend: &str) -> Result<Trainer> {
    match backend {
        "native" => Trainer::native(tc),
        "pjrt" => pjrt_trainer(tc),
        other => anyhow::bail!("unknown backend '{other}' (expected native or pjrt)"),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_trainer(tc: TrainConfig) -> Result<Trainer> {
    Trainer::pjrt(tc, ARTIFACTS)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_trainer(_tc: TrainConfig) -> Result<Trainer> {
    anyhow::bail!(
        "this binary was built without the `pjrt` feature; rebuild with \
         `cargo build --release --features pjrt` and run `make artifacts`"
    )
}

fn cmd_train(args: &[String]) -> Result<()> {
    let (cfg_file, positional, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    reject_stray_overrides(&overrides, &["train.", "wrap.", "pipeline.", "policy."])?;
    let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
    if let Some(env) = positional.first() {
        flat.insert("train.env".into(), env.clone());
    }
    let tc = config::train_config(&flat)?;
    let spec = EnvSpec::new(tc.env.as_str()).with_wrappers(tc.wrappers.iter().cloned());
    println!(
        "training {} for {} steps ({backend} backend) ...",
        spec.key(),
        tc.total_steps
    );
    let mut trainer = make_trainer(tc, &backend)?;
    let report = trainer.train()?;
    println!(
        "pipeline: env {:.0} SPS, learner {:.0} SPS, stalls {:.2}s collector / {:.2}s learner",
        report.env_sps, report.learn_sps, report.collector_stall_s, report.learner_stall_s,
    );
    println!(
        "done: {} steps @ {:.0} SPS, {} episodes, score {}, return {}",
        report.global_step,
        report.sps,
        report.episodes,
        report
            .mean_score
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
        report
            .mean_return
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
    );
    Ok(())
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let (cfg_file, positional, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    // Pull out eval-specific flags.
    let mut checkpoint = None;
    let mut episodes = 20usize;
    overrides.retain(|a| {
        if let Some(v) = a.strip_prefix("--checkpoint=") {
            checkpoint = Some(v.to_string());
            false
        } else if let Some(v) = a.strip_prefix("--episodes=") {
            episodes = v.parse().unwrap_or(20);
            false
        } else {
            true
        }
    });
    reject_stray_overrides(&overrides, &["train.", "wrap.", "pipeline.", "policy."])?;
    let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
    if let Some(env) = positional.first() {
        flat.insert("train.env".into(), env.clone());
    }
    let tc = config::train_config(&flat)?;
    let mut trainer = make_trainer(tc, &backend)?;
    if let Some(ck_path) = checkpoint {
        let ck = Checkpoint::load(&ck_path).context("loading checkpoint")?;
        trainer.restore(&ck)?;
        println!("restored checkpoint at step {}", ck.global_step);
    }
    let report = trainer.eval(episodes)?;
    println!(
        "eval: {} episodes, score {}, return {}",
        report.episodes,
        report
            .mean_score
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
        report
            .mean_return
            .map(|s| format!("{s:.3}"))
            .unwrap_or_else(|| "-".into()),
    );
    Ok(())
}

fn cmd_sweep(args: &[String]) -> Result<()> {
    let (cfg_file, _, mut overrides) = split_args(args);
    let backend = take_backend(&mut overrides);
    reject_stray_overrides(&overrides, &["train.", "wrap.", "pipeline.", "policy."])?;
    let mut solved = 0;
    for env in envs::OCEAN_ENVS {
        // Recurrent reference specs (ocean/memory) resolve an LSTM
        // default architecture and train natively — no skip needed since
        // the native backend gained BPTT.
        let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
        flat.insert("train.env".into(), env.to_string());
        let tc = config::train_config(&flat)?;
        let mut trainer = make_trainer(tc, &backend)?;
        let report = trainer.train()?;
        let score = report.mean_score.unwrap_or(0.0);
        let ok = score > 0.9;
        if ok {
            solved += 1;
        }
        println!(
            "{:<20} score {:.3}  {}",
            env,
            score,
            if ok { "SOLVED" } else { "unsolved" }
        );
    }
    println!("{solved}/{} Ocean envs solved", envs::OCEAN_ENVS.len());
    Ok(())
}

/// `puffer policy describe <env>`: print the resolved architecture —
/// per-leaf encoders, trunk/recurrence/head stages, parameter counts per
/// stage, and the checkpoint key — for debugging spec/env mismatches.
fn cmd_policy(args: &[String]) -> Result<()> {
    let sub = args.first().map(String::as_str);
    anyhow::ensure!(
        sub == Some("describe"),
        "usage: puffer policy describe <env> [--wrap.KEY=VAL ...] [--policy.KEY=VAL ...]"
    );
    let (cfg_file, positional, overrides) = split_args(&args[1..]);
    reject_stray_overrides(&overrides, &["train.", "wrap.", "policy."])?;
    let (mut flat, _) = config::load(cfg_file.as_deref(), &overrides)?;
    if let Some(env) = positional.first() {
        flat.insert("train.env".into(), env.clone());
    }
    let tc = config::train_config(&flat)?;
    let spec = EnvSpec::new(tc.env.as_str()).with_wrappers(tc.wrappers.iter().cloned());
    let pspec = tc
        .policy
        .clone()
        .unwrap_or_else(|| pufferlib::policy::PolicySpec::default_for(&tc.env));
    let probe = spec.build(0);
    let backend = pufferlib::backend::NativeBackend::for_env_with_policy(
        &spec.key(),
        probe.as_ref(),
        &pspec,
    )?;
    println!(
        "{} — resolved architecture (checkpoint key: {})",
        spec.key(),
        backend.key()
    );
    print!("{}", backend.arch().describe());
    Ok(())
}

fn cmd_autotune(args: &[String]) -> Result<()> {
    let (_, positional, overrides) = split_args(args);
    let env = positional
        .first()
        .cloned()
        .unwrap_or_else(|| "ocean/squared".into());
    let mut num_envs = 8;
    let mut workers = 4;
    let mut secs = 1.0f64;
    let mut wrap_overrides = Vec::new();
    for a in overrides {
        if let Some(v) = a.strip_prefix("--envs=") {
            num_envs = v.parse().map_err(|_| anyhow::anyhow!("--envs: cannot parse '{v}'"))?;
        } else if let Some(v) = a.strip_prefix("--workers=") {
            workers = v.parse().map_err(|_| anyhow::anyhow!("--workers: cannot parse '{v}'"))?;
        } else if let Some(v) = a.strip_prefix("--secs=") {
            secs = v.parse().map_err(|_| anyhow::anyhow!("--secs: cannot parse '{v}'"))?;
        } else {
            wrap_overrides.push(a);
        }
    }
    // Remaining overrides are --wrap.* knobs: tune with the exact
    // pipeline you will train with.
    reject_stray_overrides(&wrap_overrides, &["wrap."])?;
    let (flat, _) = config::load(None, &wrap_overrides)?;
    config::validate_keys(&flat)?;
    let spec = EnvSpec::new(env.as_str()).with_wrappers(config::wrap_config(&flat)?);
    println!(
        "autotuning {} with {num_envs} envs (≤{workers} workers, {secs}s per config) ...",
        spec.key()
    );
    let results = autotune::autotune(&spec, num_envs, workers, secs)?;
    print!("{}", autotune::format_results(&results));
    println!(
        "\nrecommended: {} (num_workers={}, batch_size={}, zero_copy={})",
        results[0].label,
        results[0].cfg.num_workers,
        results[0].cfg.batch_size,
        results[0].cfg.zero_copy
    );
    Ok(())
}
