//! Fleet-scale experiment ops: the run registry, resumable sweeps, and
//! the `puffer ps` / `puffer top` live watch (ROADMAP north-star item
//! 5 — one durable, machine-readable record per experiment instead of
//! loose `metrics.csv` directories).
//!
//! ## The registry
//!
//! Every `RunSpec` launch is logged under a registry root (default
//! `runs/`, the `[runs]` spec section / `--runs.root` flag):
//!
//! ```text
//! runs/
//!   index.jsonl                  # append-only event log, fsync'd: one
//!                                #   line per status transition
//!   <run_dir>/run.json           # the authoritative per-run record,
//!                                #   rewritten atomically per transition
//!   <run_dir>/heartbeat.json     # live SPS/stall telemetry, rewritten
//!                                #   atomically once per period
//! ```
//!
//! Records transition `pending → running → done | failed | killed`
//! with host/pid, start/end times, attempt count, final metrics, and
//! checkpoint path. Both write shapes ([`fsio`]) are crash-safe, so a
//! SIGKILL at any point leaves a parseable registry — the property the
//! resume path builds on.
//!
//! ## Resumable sweeps
//!
//! `puffer sweep` consults the registry before launching each grid
//! child ([`sweep::classify`]): at-budget children are skipped,
//! partials resume from their checkpoints via the zero-flag resume
//! path, and orphans (stale heartbeat, dead pid) are reclaimed. With
//! `--processes=N` the children run as separate OS processes
//! ([`sweep::run_processes`]) so a child panic/OOM/SIGKILL costs that
//! child alone, with its exit status captured into the registry.
//!
//! ## Live watch
//!
//! Trainers heartbeat env-SPS / learner-SPS / stall counters to
//! `heartbeat.json` ([`heartbeat::HeartbeatWriter`]); `puffer ps`
//! ([`watch::ps_table`], `--json` for scripts) tables live/recent runs
//! with stale-heartbeat orphan detection, and `puffer top`
//! ([`watch::top_frame`]) refreshes the in-flight view.

// Registry plumbing is pure std-file I/O over safe primitives; the
// crate's unsafe surface stays in vector/ (CONCURRENCY.md).
#![forbid(unsafe_code)]

pub mod fsio;
pub mod heartbeat;
pub mod record;
pub mod registry;
pub mod sweep;
pub mod watch;

pub use heartbeat::{Heartbeat, HeartbeatWriter};
pub use record::{FinalMetrics, RunRecord, RunStatus};
pub use registry::Registry;
pub use watch::{ps_json, ps_table, snapshot, top_frame, DerivedStatus, RunView};

/// The strict `[runs]` section of a [`RunSpec`](crate::runspec::RunSpec)
/// and the `--runs.*` CLI namespace. Plain data, TOML/JSON
/// round-trippable like every other spec part; `None` on a spec means
/// "defaults" — registry logging is always on for runs with a run dir.
#[derive(Clone, Debug, PartialEq)]
pub struct RunsConfig {
    /// Registry root: where `index.jsonl` lives. Relative paths resolve
    /// against the working directory, like `train.run_dir`.
    pub root: String,
    /// Heartbeat period in seconds. Staleness is judged at
    /// `max(3 × period, 10 s)` ([`heartbeat::stale_after_s`]).
    pub heartbeat_s: f64,
}

impl Default for RunsConfig {
    fn default() -> Self {
        RunsConfig {
            root: "runs".to_string(),
            heartbeat_s: 5.0,
        }
    }
}

impl RunsConfig {
    /// The flat `runs.*` pairs (serialization form, mirroring
    /// [`ServeConfig`](crate::serve::ServeConfig)).
    pub fn to_flat_pairs(&self) -> Vec<(&'static str, String)> {
        vec![
            ("root", self.root.clone()),
            ("heartbeat_s", fmt_f64(self.heartbeat_s)),
        ]
    }

    /// The effective config for a spec: its `[runs]` section, or
    /// defaults when the section is absent.
    pub fn for_spec(spec: &crate::runspec::RunSpec) -> RunsConfig {
        spec.runs.clone().unwrap_or_default()
    }
}

/// Format an f64 so it round-trips through the flat string form
/// (integral values print without a fraction, like the JSON dumper).
fn fmt_f64(x: f64) -> String {
    if x == x.trunc() && x.is_finite() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_pairs_round_trip_defaults() {
        let cfg = RunsConfig::default();
        let pairs = cfg.to_flat_pairs();
        assert_eq!(
            pairs,
            vec![
                ("root", "runs".to_string()),
                ("heartbeat_s", "5".to_string()),
            ]
        );
        assert_eq!(fmt_f64(2.5), "2.5");
        assert_eq!(fmt_f64(5.0), "5");
    }
}
