//! Continuous-action support — the paper lists this as its first
//! limitation (§8: *"PufferLib does not yet support continuous action
//! spaces. This is a relatively straightforward feature planned for within
//! the next few minor updates."*). This module implements the planned
//! extension at the emulation level: a continuous `Box` action space is
//! emulated as a MultiDiscrete over a fixed quantization grid, with an
//! exact dequantization inverse — the same "looks like Atari" trick the
//! emulation layer plays on observations.
//!
//! The grid resolution is configurable; 15 bins per dimension is enough
//! for classic control tasks, and downstream users who need true Gaussian
//! heads can still consume the flat observation path and bring their own
//! actor (the emulation layer never constrains the model).

use crate::spaces::{Space, Value};

/// Quantization wrapper for a continuous `Box` action space.
#[derive(Clone, Debug)]
pub struct QuantizedActions {
    low: f32,
    high: f32,
    dims: usize,
    bins: usize,
}

impl QuantizedActions {
    /// Build from a `Box` action space. Errors on non-Box spaces.
    pub fn new(space: &Space, bins: usize) -> Option<Self> {
        assert!(bins >= 2, "need at least 2 bins");
        match space {
            Space::Box {
                shape, low, high, ..
            } => Some(QuantizedActions {
                low: *low,
                high: *high,
                dims: shape.iter().product::<usize>().max(1),
                bins,
            }),
            _ => None,
        }
    }

    /// The emulated MultiDiscrete dims: `bins` choices per continuous dim.
    pub fn action_dims(&self) -> Vec<usize> {
        vec![self.bins; self.dims]
    }

    /// Map discrete slot choices back to continuous values (bin centers).
    pub fn dequantize(&self, slots: &[i32]) -> Value {
        debug_assert_eq!(slots.len(), self.dims);
        let step = (self.high - self.low) / (self.bins as f32 - 1.0);
        Value::F32(
            slots
                .iter()
                .map(|&s| self.low + step * s as f32)
                .collect(),
        )
    }

    /// Map a continuous action to the nearest grid slots (round trip
    /// partner of [`dequantize`](Self::dequantize); used by tests and by
    /// imitation-style pipelines).
    pub fn quantize(&self, v: &Value) -> Vec<i32> {
        // PANIC: quantize's contract — callers hand the env's continuous (F32) action value.
        let xs = v.as_f32s().expect("continuous action must be F32");
        debug_assert_eq!(xs.len(), self.dims);
        let step = (self.high - self.low) / (self.bins as f32 - 1.0);
        xs.iter()
            .map(|&x| {
                (((x - self.low) / step).round() as i32).clamp(0, self.bins as i32 - 1)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{check, CheckConfig};
    use crate::util::rng::Rng;

    fn space() -> Space {
        Space::boxf(&[3], -2.0, 2.0)
    }

    #[test]
    fn rejects_discrete_spaces() {
        assert!(QuantizedActions::new(&Space::Discrete(4), 15).is_none());
    }

    #[test]
    fn grid_shape() {
        let q = QuantizedActions::new(&space(), 15).unwrap();
        assert_eq!(q.action_dims(), vec![15, 15, 15]);
    }

    #[test]
    fn dequantize_hits_bounds_and_center() {
        let q = QuantizedActions::new(&space(), 5).unwrap();
        let v = q.dequantize(&[0, 2, 4]);
        assert_eq!(v.as_f32s().unwrap(), &[-2.0, 0.0, 2.0]);
    }

    #[test]
    fn quantize_dequantize_round_trip_property() {
        let q = QuantizedActions::new(&space(), 31).unwrap();
        let step = 4.0 / 30.0;
        check(
            CheckConfig::default(),
            |rng: &mut Rng| Value::F32((0..3).map(|_| rng.uniform(-2.0, 2.0)).collect()),
            |v| {
                let slots = q.quantize(v);
                let back = q.dequantize(&slots);
                let orig = v.as_f32s().unwrap();
                let rec = back.as_f32s().unwrap();
                for (o, r) in orig.iter().zip(rec) {
                    if (o - r).abs() > step / 2.0 + 1e-5 {
                        return Err(format!("{o} -> {r} exceeds half-step"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn grid_points_exactly_recovered() {
        let q = QuantizedActions::new(&space(), 9).unwrap();
        for s in 0..9 {
            let v = q.dequantize(&[s, s, s]);
            assert_eq!(q.quantize(&v), vec![s, s, s]);
        }
    }
}
