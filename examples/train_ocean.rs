//! **End-to-end driver**: train Clean PuffeRL on the full Ocean suite and
//! report solve status — the paper's §4 claim that every env is solved
//! (score > 0.9) in roughly 30k interactions with one barely-tuned
//! hyperparameter set.
//!
//! Each run is one declarative [`RunSpec`] — env × policy × vec × train
//! × seed — the same value `puffer run <spec.toml>` executes and every
//! checkpoint embeds (see `examples/specs/` for the file form).
//! ocean/memory needs recurrence to be solvable: its spec pins the LSTM
//! sandwich at 48 wide (the scalar BPTT is the one genuinely expensive
//! cell, and a 48-wide LSTM solves it).
//!
//! Everything composes here: Rust coordinator (emulation + vectorization
//! + PPO loop) → the `PolicyBackend` learner math. The default build uses
//! the pure-Rust `NativeBackend`, so this runs on a clean machine with no
//! artifacts and no Python:
//!
//! ```bash
//! cargo run --release --example train_ocean
//! ```
//!
//! Env names as args restrict the sweep: `... train_ocean ocean/memory`.

use pufferlib::envs;
use pufferlib::policy::PolicySpec;
use pufferlib::runspec::{RunSpec, RunSpecExt as _};
use pufferlib::vector::VecSpec;
use pufferlib::wrappers::EnvSpec;

/// Per-env spec: one base configuration, with the paper's "barely
/// tuned" caveat applied as a small multiplier for the two slowest
/// learners (squared's long credit chain, memory's recurrence).
fn spec_for(env: &str) -> RunSpec {
    let base = RunSpec::new(EnvSpec::new(env))
        .with_vec(VecSpec::mt(2))
        .with_seed(1)
        .with_train(|t| {
            t.total_steps = 30_000;
            t.lr = 3e-3;
            t.ent_coef = 0.005;
            t.epochs = 4;
            t.anneal_lr = true;
            t.log_every = 10;
            // Serial loop, full-batch updates: the reference solve
            // settings. Flip pipeline_depth to 1 (and raise minibatches)
            // for the overlapped collector/learner pipeline — see README
            // "Throughput tuning".
            t.run_dir = Some(format!("runs/{}", env.replace('/', "_")));
        });
    match env {
        "ocean/squared" => base.with_train(|t| {
            t.total_steps = 150_000;
            t.ent_coef = 0.002;
        }),
        "ocean/spaces" => base.with_train(|t| {
            t.total_steps = 150_000;
            t.lr = 8e-3;
            t.ent_coef = 0.002;
        }),
        "ocean/memory" => base
            // The LSTM sandwich, sized down: a 48-wide trunk/state is
            // plenty for 3-bit recall and keeps scalar BPTT fast.
            .with_policy(PolicySpec::default().with_hidden(48).with_lstm(48))
            .with_train(|t| {
                t.total_steps = 120_000;
                t.lr = 2.5e-3;
                t.ent_coef = 0.01;
            }),
        _ => base,
    }
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let selected: Vec<&str> = if args.is_empty() {
        envs::OCEAN_ENVS.to_vec()
    } else {
        args.iter().map(String::as_str).collect()
    };

    println!("=== Ocean end-to-end training sweep (paper §4 / bench C3) ===\n");
    let mut rows = Vec::new();
    for env in &selected {
        let spec = spec_for(env);
        let steps = spec.train.total_steps;
        let mut trainer = spec.build()?;
        let report = trainer.train()?;
        // When did the curve first cross 0.9?
        let solved_at = report
            .score_curve
            .iter()
            .find(|(_, s)| *s > 0.9)
            .map(|(step, _)| *step);
        rows.push((
            env.to_string(),
            steps,
            report.mean_score.unwrap_or(0.0),
            solved_at,
            report.sps,
            report.episodes,
        ));
    }

    println!("\n| env | budget | final score | solved@ | SPS | episodes |");
    println!("|---|---|---|---|---|---|");
    let mut solved = 0;
    for (env, steps, score, solved_at, sps, eps) in &rows {
        if *score > 0.9 {
            solved += 1;
        }
        println!(
            "| {env} | {steps} | {score:.3} | {} | {sps:.0} | {eps} |",
            solved_at
                .map(|s| s.to_string())
                .unwrap_or_else(|| "-".into())
        );
    }
    println!("\n{solved}/{} solved (score > 0.9)", rows.len());
    println!("paper claim: every Ocean env solved in ~30k interactions (§4)");
    Ok(())
}
