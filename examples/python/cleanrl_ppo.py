#!/usr/bin/env python3
"""CleanRL-style PPO against the Rust vectorizer, in pure numpy.

The loop is structurally identical to CleanRL's ``ppo.py`` — vectorized
rollout collection, GAE, flattened minibatches, clipped surrogate +
value loss + entropy bonus, Adam — with the torch model swapped for a
linear softmax policy/value head with hand-written gradients, so the
example runs anywhere the wheel installs (no torch in the test image).

The env side is the point: ``pufferlib.emulate(...)`` drops in exactly
where CleanRL constructs ``gym.vector.SyncVectorEnv`` and the rest of
the script doesn't know the difference.

    python examples/python/cleanrl_ppo.py                  # classic/cartpole
    python examples/python/cleanrl_ppo.py --smoke          # CI: ocean/bandit,
                                                           # assert > random

The --smoke run is the acceptance check wired into the CI pybind job:
ocean/bandit pays Bernoulli(0.9) on its best arm and Bernoulli(0.3) on
the rest (random play scores 0.45), and 100 PPO updates must push the
greedy policy above 0.6.
"""

import argparse
import sys

import numpy as np

import pufferlib


def softmax(z):
    z = z - z.max(axis=-1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=-1, keepdims=True)


class Adam:
    def __init__(self, params, lr):
        self.lr, self.t = lr, 0
        self.m = {k: np.zeros_like(v) for k, v in params.items()}
        self.v = {k: np.zeros_like(v) for k, v in params.items()}

    def step(self, params, grads, b1=0.9, b2=0.999, eps=1e-8):
        self.t += 1
        for k in params:
            self.m[k] = b1 * self.m[k] + (1 - b1) * grads[k]
            self.v[k] = b2 * self.v[k] + (1 - b2) * grads[k] ** 2
            m_hat = self.m[k] / (1 - b1**self.t)
            v_hat = self.v[k] / (1 - b2**self.t)
            params[k] -= self.lr * m_hat / (np.sqrt(v_hat) + eps)


def train(env_name, num_envs, rollout, updates, lr, seed, clip=0.2,
          gamma=0.99, lam=0.95, epochs=4, minibatches=4, ent_coef=0.01,
          vf_coef=0.5, log_every=10):
    envs = pufferlib.emulate(env_name, num_envs=num_envs)
    obs_dim = int(np.prod(envs.single_observation_space.shape))
    n_act = int(envs.single_action_space.n)
    rng = np.random.default_rng(seed)
    params = {
        "W": np.zeros((obs_dim, n_act)),
        "b": np.zeros(n_act),
        "w": np.zeros(obs_dim),
        "c": np.zeros(1),
    }
    opt = Adam(params, lr)

    def policy(x):
        return softmax(x @ params["W"] + params["b"])

    def value(x):
        return x @ params["w"] + params["c"][0]

    next_obs, _ = envs.reset(seed=seed)
    next_obs = np.array(next_obs, copy=True).reshape(num_envs, obs_dim)
    ep_returns = []

    for update in range(1, updates + 1):
        # -- rollout ------------------------------------------------------
        O = np.zeros((rollout, num_envs, obs_dim))
        A = np.zeros((rollout, num_envs), dtype=np.int64)
        LP = np.zeros((rollout, num_envs))
        R = np.zeros((rollout, num_envs))
        D = np.zeros((rollout, num_envs))
        V = np.zeros((rollout, num_envs))
        for t in range(rollout):
            O[t] = next_obs
            probs = policy(next_obs)
            A[t] = (probs.cumsum(axis=1) > rng.random((num_envs, 1))).argmax(axis=1)
            LP[t] = np.log(probs[np.arange(num_envs), A[t]] + 1e-12)
            V[t] = value(next_obs)
            obs, rew, term, trunc, infos = envs.step(A[t])
            # zero-copy views: stage into our own storage, like CleanRL does
            next_obs = np.array(obs, copy=True).reshape(num_envs, obs_dim)
            R[t] = rew
            D[t] = np.logical_or(term, trunc)
            if "episode_return" in infos:
                mask = infos["_episode_return"]
                ep_returns.extend(infos["episode_return"][mask].tolist())

        # -- GAE ----------------------------------------------------------
        adv = np.zeros_like(R)
        last = 0.0
        next_value = value(next_obs)
        for t in reversed(range(rollout)):
            nonterminal = 1.0 - D[t]
            nv = next_value if t == rollout - 1 else V[t + 1]
            delta = R[t] + gamma * nv * nonterminal - V[t]
            adv[t] = last = delta + gamma * lam * nonterminal * last
        returns = adv + V

        # -- flattened minibatch epochs ----------------------------------
        X = O.reshape(-1, obs_dim)
        a = A.reshape(-1)
        lp_old = LP.reshape(-1)
        adv_f = adv.reshape(-1)
        if adv_f.std() > 1e-8:
            adv_f = (adv_f - adv_f.mean()) / (adv_f.std() + 1e-8)
        ret_f = returns.reshape(-1)
        n = len(a)
        idx = np.arange(n)
        for _ in range(epochs):
            rng.shuffle(idx)
            for mb in np.array_split(idx, minibatches):
                x, act, advm = X[mb], a[mb], adv_f[mb]
                m = len(mb)
                p = policy(x)
                lp = np.log(p[np.arange(m), act] + 1e-12)
                ratio = np.exp(lp - lp_old[mb])
                clipped = np.clip(ratio, 1 - clip, 1 + clip)
                use = (ratio * advm <= clipped * advm).astype(np.float64)
                # d(pg_loss)/d(logp): the clipped branch is constant in theta
                dlogp = -(advm * ratio * use) / m
                onehot = np.eye(n_act)[act]
                dz = dlogp[:, None] * (onehot - p)
                logp_full = np.log(p + 1e-12)
                H = -(p * logp_full).sum(axis=1)
                dz += ent_coef * p * (logp_full + H[:, None]) / m
                v = value(x)
                dv = vf_coef * (v - ret_f[mb]) / m
                grads = {
                    "W": x.T @ dz,
                    "b": dz.sum(axis=0),
                    "w": x.T @ dv,
                    "c": np.array([dv.sum()]),
                }
                opt.step(params, grads)

        if update % log_every == 0 or update == updates:
            recent = np.mean(ep_returns[-200:]) if ep_returns else float("nan")
            print(f"update {update:4d}  episode_return {recent:8.3f}  "
                  f"mean_step_reward {R.mean():6.3f}")

    return envs, params


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--env", default="classic/cartpole")
    ap.add_argument("--num-envs", type=int, default=16)
    ap.add_argument("--rollout", type=int, default=32)
    ap.add_argument("--updates", type=int, default=200)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: 100 updates on ocean/bandit, assert the "
                         "greedy policy beats random (0.45)")
    args = ap.parse_args()

    if args.smoke:
        args.env, args.updates, args.rollout, args.lr = "ocean/bandit", 100, 8, 0.05

    envs, params = train(args.env, args.num_envs, args.rollout, args.updates,
                         args.lr, args.seed)

    if args.smoke:
        # Greedy evaluation: constant obs, so the policy is its bias row.
        obs, _ = envs.reset(seed=123)
        x = np.array(obs, copy=True).reshape(args.num_envs, -1)
        best = int(np.argmax(x[0] @ params["W"] + params["b"]))
        total = 0.0
        rounds = 20
        for _ in range(rounds):
            _, rew, _, _, _ = envs.step(np.full(args.num_envs, best, dtype=np.int64))
            total += float(np.asarray(rew, dtype=np.float64).mean())
        mean_reward = total / rounds
        envs.close()
        print(f"smoke: greedy arm {best} mean reward {mean_reward:.3f} "
              f"(random = 0.45, best arm = 0.9)")
        if mean_reward <= 0.6:
            print("smoke FAILED: policy did not beat random", file=sys.stderr)
            return 1
        print("smoke PASSED")
        return 0

    envs.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
