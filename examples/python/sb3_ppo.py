#!/usr/bin/env python3
"""Stable-Baselines3 PPO on the Rust vectorizer — unmodified SB3.

``pufferlib.sb3.make_sb3_env`` stands in for ``make_vec_env``; SB3's own
``PPO`` class does the training. Exits cleanly with a pointer to the
extra dependency when stable-baselines3 (or torch) is not installed, so
the example is safe to invoke from CI on images without torch.

    python examples/python/sb3_ppo.py --timesteps 8192
"""

import argparse
import sys


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--env", default="classic/cartpole")
    ap.add_argument("--num-envs", type=int, default=8)
    ap.add_argument("--timesteps", type=int, default=8192)
    args = ap.parse_args()

    try:
        from stable_baselines3 import PPO
    except ImportError:
        print(
            "stable-baselines3 not installed — skipping "
            "(pip install 'pufferlib[sb3]' to run this example)"
        )
        return 0

    from pufferlib.sb3 import make_sb3_env

    venv = make_sb3_env(args.env, num_envs=args.num_envs)
    model = PPO("MlpPolicy", venv, n_steps=128, batch_size=256, verbose=1)
    model.learn(total_timesteps=args.timesteps)
    venv.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
