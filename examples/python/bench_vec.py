#!/usr/bin/env python3
"""Real Python-driven vectorization benchmark: puffer-py vs Gymnasium.

This replaces the *simulated* Gymnasium/SB3 comparators in
``crates/puffer-core/src/vector/baselines`` with actual measurements:
the same CartPole workload stepped through (a) the Rust vectorizer via
the zero-copy ``pufferlib.emulate`` adapter, (b) the raw native handle
(adapter overhead isolated), and (c) ``gymnasium.vector.SyncVectorEnv``
over the pure-Python ``CartPole-v1``.

Steps/s counts env-steps (``num_envs`` per ``step()`` call). Writes
machine-readable results to ``$PUFFER_BENCH_JSON`` when set — ``make
bench-py`` sets it to ``BENCH_pybind.json``, matching the Rust bench
convention.

    python examples/python/bench_vec.py --num-envs 32 --steps 2000
"""

import argparse
import json
import os
import sys
import time

import numpy as np

import pufferlib


def bench_adapter(env_name, num_envs, steps, **kwargs):
    envs = pufferlib.emulate(env_name, num_envs=num_envs, **kwargs)
    actions = np.zeros(num_envs, dtype=np.int32)
    envs.reset(seed=0)
    envs.step(actions)  # warm the view cache
    t0 = time.perf_counter()
    for _ in range(steps):
        envs.step(actions)
    elapsed = time.perf_counter() - t0
    envs.close()
    return num_envs * steps / elapsed


def bench_raw(env_name, num_envs, steps):
    v = pufferlib.raw_vecenv(env_name, num_envs)
    slots = len(v.action_dims())
    actions = [0] * (num_envs * slots)
    v.async_reset(0)
    rows, *_ = v.recv()
    v.send(actions)
    t0 = time.perf_counter()
    for _ in range(steps):
        v.recv()
        v.send(actions)
    elapsed = time.perf_counter() - t0
    v.close()
    return num_envs * steps / elapsed


def bench_gymnasium(num_envs, steps):
    try:
        import gymnasium
    except ImportError:
        return None
    envs = gymnasium.vector.SyncVectorEnv(
        [lambda: gymnasium.make("CartPole-v1") for _ in range(num_envs)]
    )
    actions = np.zeros(num_envs, dtype=np.int64)
    envs.reset(seed=0)
    envs.step(actions)
    t0 = time.perf_counter()
    for _ in range(steps):
        envs.step(actions)
    elapsed = time.perf_counter() - t0
    envs.close()
    return num_envs * steps / elapsed


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--num-envs", type=int, default=32)
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--workers", type=int, default=4)
    args = ap.parse_args()
    n, steps = args.num_envs, args.steps

    rows = [
        ("puffer/serial", "classic/cartpole",
         bench_adapter("classic/cartpole", n, steps)),
        ("puffer/mt", "classic/cartpole",
         bench_adapter("classic/cartpole", n, steps, vec="mt", workers=args.workers)),
        ("puffer/raw-serial", "classic/cartpole",
         bench_raw("classic/cartpole", n, steps)),
        ("gymnasium/sync", "CartPole-v1", bench_gymnasium(n, steps)),
    ]

    print(f"# pybind vectorization bench — {n} envs x {steps} steps")
    print(f"| {'backend':<18} | {'env':<16} | {'steps/s':>12} | {'us/step':>10} |")
    print(f"|{'-' * 20}|{'-' * 18}|{'-' * 14}|{'-' * 12}|")
    for backend, env, sps in rows:
        if sps is None:
            print(f"| {backend:<18} | {env:<16} | {'-':>12} | {'-':>10} |")
            continue
        us = 1e6 * n / sps
        print(f"| {backend:<18} | {env:<16} | {sps:>12.0f} | {us:>10.1f} |")

    path = os.environ.get("PUFFER_BENCH_JSON")
    if path:
        out = {
            "bench": "pybind_vector",
            "method": "measured",
            "num_envs": n,
            "steps": steps,
            "rows": [
                {
                    "backend": backend,
                    "env": env,
                    "sps": sps,
                    "us_per_step_batch": None if sps is None else 1e6 * n / sps,
                }
                for backend, env, sps in rows
            ],
        }
        with open(path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"\n# wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
