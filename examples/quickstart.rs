//! Quickstart: wrap an environment, vectorize it, run a random rollout.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pufferlib::prelude::*;
use pufferlib::util::timer::SpsCounter;

fn main() -> anyhow::Result<()> {
    // 1. Describe the env as an EnvSpec: any first-party name (or a
    //    custom env via EnvSpec::custom — see examples/custom_env.rs)
    //    plus a one-line wrapper chain, applied innermost first.
    let spec = EnvSpec::new("ocean/squared").clip_reward(1.0).stack(2);

    // 2. Describe the vectorization as a VecSpec — the same declarative
    //    value a RunSpec's [vec] section deserializes into: 2 workers,
    //    EnvPool batch of 4 envs (first finishers win). Resolving it
    //    against the env budget yields the validated low-level
    //    VecConfig; the slabs size themselves from the *wrapped* layout
    //    (stacking doubled the rows here).
    let vec = VecSpec::Mt {
        workers: 2,
        batch: VecBatch::Envs(4),
        zero_copy: false,
        spin_budget: 64,
    };
    let mut venv = Multiprocessing::from_spec(&spec, vec.resolve(8, 0)?)?;
    println!(
        "{}: {} envs, batch {}, mode {:?}, obs {}B ({} f32), actions {:?}",
        spec.key(),
        venv.num_envs(),
        venv.batch_size(),
        venv.mode(),
        venv.obs_layout().byte_len(),
        venv.obs_layout().flat_len(),
        venv.action_dims(),
    );

    // 3. Drive it with random actions.
    let mut rng = Rng::new(0);
    let slots = venv.action_dims().len();
    let dims: Vec<usize> = venv.action_dims().to_vec();
    let rows = venv.batch_rows();
    let mut sps = SpsCounter::new();
    let mut episodes = 0usize;

    venv.async_reset(42);
    for _ in 0..2000 {
        let batch = venv.recv()?;
        episodes += batch
            .infos
            .iter()
            .filter(|(_, i)| i.iter().any(|(k, _)| *k == "episode_return"))
            .count();
        let actions: Vec<i32> = (0..rows)
            .flat_map(|_| dims.iter().map(|&n| rng.below(n as u64) as i32).collect::<Vec<_>>())
            .collect();
        debug_assert_eq!(actions.len(), rows * slots);
        venv.send(&actions)?;
        sps.add(venv.batch_size() as u64);
    }
    println!(
        "random rollout: {:.0} env-steps/sec, {episodes} episodes completed",
        sps.overall()
    );
    Ok(())
}
