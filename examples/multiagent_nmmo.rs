//! Multiagent at scale: the Neural-MMO-profile simulator (variable
//! population, Dict observations, structured Dict actions) driven through
//! emulation + pooled vectorization, with the policy computing actions
//! for every alive agent — the paper's §7 Neural MMO use case in
//! miniature. Runs on the default pure-Rust backend (no artifacts, no
//! Python):
//!
//! ```bash
//! cargo run --release --example multiagent_nmmo
//! ```

use pufferlib::backend::NativeBackend;
use pufferlib::policy::Policy;
use pufferlib::prelude::PolicyBackend as _;
use pufferlib::util::stats::Welford;
use pufferlib::util::timer::SpsCounter;
use pufferlib::vector::{Multiprocessing, VecConfig, VecEnv};
use pufferlib::wrappers::EnvSpec;
use pufferlib::{envs, envs::profile};

fn main() -> anyhow::Result<()> {
    // 2 envs × 16 agent rows = 32 global rows; pooled batch = 1 env (16
    // rows) so the policy overlaps with simulation.
    let cfg = VecConfig {
        num_envs: 2,
        num_workers: 2,
        batch_size: 1,
        ..Default::default()
    };
    let spec = EnvSpec::new("profile/nmmo");
    let mut venv = Multiprocessing::from_spec(&spec, cfg)?;
    println!(
        "nmmo-sim: {} envs × {} agents, obs {} f32 (dict: tiles i32[15,15] + entities f32[8,6] + stats f32[10]), actions {:?}",
        venv.num_envs(),
        venv.agents_per_env(),
        venv.obs_layout().flat_len(),
        venv.action_dims(),
    );
    assert_eq!(venv.agents_per_env(), profile::nmmo_max_agents());

    let probe = envs::make("profile/nmmo", 0);
    let mut backend = NativeBackend::for_env("profile/nmmo", probe.as_ref())?;
    drop(probe);
    assert_eq!(backend.spec().obs_dim, venv.obs_layout().flat_len());
    let mut policy = Policy::new(&mut backend, 7)?;
    let layout = venv.obs_layout().clone();
    let d = layout.flat_len();
    let agents = venv.agents_per_env();

    let mut sps = SpsCounter::new();
    let mut pop = Welford::new();
    let mut episodes = 0;

    venv.async_reset(3);
    for _ in 0..40 {
        let (obs_f32, global_rows, alive_rows) = {
            let b = venv.recv()?;
            let mut obs_f32 = vec![0.0f32; b.env_ids.len() * agents * d];
            for (i, row) in b.obs.chunks_exact(layout.byte_len()).enumerate() {
                layout.row_to_f32(row, &mut obs_f32[i * d..(i + 1) * d]);
            }
            let mut rows = Vec::new();
            for &e in b.env_ids {
                for a in 0..agents {
                    rows.push(e * agents + a);
                }
            }
            // Padded (dead) rows read as terminated: count live agents.
            let alive = b.terms.iter().filter(|&&t| !t).count();
            episodes += b
                .infos
                .iter()
                .filter(|(_, i)| i.iter().any(|(k, _)| *k == "num_agents"))
                .count();
            (obs_f32, rows, alive)
        };
        pop.push(alive_rows as f64);
        let out = policy.step(&mut backend, &obs_f32, &global_rows)?;
        venv.send(&out.actions)?;
        sps.add((global_rows.len() / agents) as u64);
    }

    println!(
        "ran {} env-steps ({:.0} env-steps/s incl. policy), population mean {:.1} (min {:.0}, max {:.0}), {} episode resets",
        sps.total(),
        sps.overall(),
        pop.mean(),
        pop.min(),
        pop.max(),
        episodes
    );
    println!("padding + canonical agent sort handled by PufferMultiEnv (paper §3.1)");
    Ok(())
}
