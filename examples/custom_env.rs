//! How a downstream user brings their own environment: implement
//! [`StructuredEnv`] with whatever space tree fits the problem, wrap with
//! `PufferEnv::new` (the paper's one-line wrapper), and everything —
//! vectorization, pooling, training — just works. No registry required.
//!
//! ```bash
//! cargo run --release --example custom_env
//! ```

use pufferlib::emulation::{Info, PufferEnv, StructuredEnv};
use pufferlib::prelude::*;
use pufferlib::vector::{Serial, VecConfig};

/// A toy foraging world with a deliberately awkward observation space:
/// a u8 tile patch, an f32 stat block, and a Discrete compass — the kind
/// of structure that breaks naive RL tooling (paper §3.1).
struct Forage {
    pos: (i32, i32),
    food: (i32, i32),
    energy: f32,
    t: u32,
    rng: Rng,
}

const N: i32 = 9;

impl Forage {
    fn new() -> Self {
        Forage {
            pos: (0, 0),
            food: (0, 0),
            energy: 1.0,
            t: 0,
            rng: Rng::new(0),
        }
    }

    fn obs(&self) -> Value {
        // 3x3 patch around the agent: 1 if food there.
        let mut patch = vec![0u8; 9];
        for dy in -1..=1 {
            for dx in -1..=1 {
                if (self.pos.0 + dx, self.pos.1 + dy) == self.food {
                    patch[((dy + 1) * 3 + dx + 1) as usize] = 1;
                }
            }
        }
        let compass = match (
            (self.food.0 - self.pos.0).signum(),
            (self.food.1 - self.pos.1).signum(),
        ) {
            (1, _) => 0,
            (-1, _) => 1,
            (_, 1) => 2,
            _ => 3,
        };
        Value::Dict(vec![
            ("compass".into(), Value::Discrete(compass)),
            ("patch".into(), Value::U8(patch)),
            ("stats".into(), Value::F32(vec![self.energy, self.t as f32 / 64.0])),
        ])
    }
}

impl StructuredEnv for Forage {
    fn observation_space(&self) -> Space {
        Space::dict(vec![
            ("patch".into(), Space::boxu8(&[3, 3])),
            ("stats".into(), Space::boxf(&[2], -10.0, 10.0)),
            ("compass".into(), Space::Discrete(4)),
        ])
    }

    fn action_space(&self) -> Space {
        Space::Discrete(4) // N/S/E/W
    }

    fn reset(&mut self, seed: u64) -> Value {
        self.rng = Rng::new(seed);
        self.pos = (self.rng.range_i64(0, (N - 1) as i64) as i32, 0);
        self.food = (
            self.rng.range_i64(0, (N - 1) as i64) as i32,
            self.rng.range_i64(1, (N - 1) as i64) as i32,
        );
        self.energy = 1.0;
        self.t = 0;
        self.obs()
    }

    fn step(&mut self, action: &Value) -> (Value, f32, bool, bool, Info) {
        let a = action.as_discrete().unwrap();
        let (dx, dy) = [(0, -1), (0, 1), (1, 0), (-1, 0)][a as usize];
        self.pos.0 = (self.pos.0 + dx).clamp(0, N - 1);
        self.pos.1 = (self.pos.1 + dy).clamp(0, N - 1);
        self.energy -= 0.02;
        self.t += 1;
        let found = self.pos == self.food;
        let starved = self.energy <= 0.0 || self.t >= 64;
        let reward = if found { 1.0 } else { -0.01 };
        let mut info = Info::new();
        if found || starved {
            info.push(("score", if found { 1.0 } else { 0.0 }));
        }
        (self.obs(), reward, found, starved && !found, info)
    }
}

fn main() -> anyhow::Result<()> {
    // One line: any structured env becomes vectorization-ready. The
    // EnvSpec carries the custom constructor plus any wrapper chain
    // (here: clip the sparse find-the-food reward), and every layer of
    // the stack consumes the spec.
    let spec =
        EnvSpec::custom("forage", |_| Box::new(PufferEnv::new(Forage::new())) as Box<dyn FlatEnv>)
            .clip_reward(0.5);
    let cfg = VecConfig {
        num_envs: 4,
        num_workers: 1,
        batch_size: 4,
        ..Default::default()
    };
    let mut venv = Serial::from_spec(&spec, cfg)?;

    println!(
        "custom env emulated: {} obs bytes -> {} f32 features, action dims {:?}",
        venv.obs_layout().byte_len(),
        venv.obs_layout().flat_len(),
        venv.action_dims()
    );
    for f in venv.obs_layout().fields() {
        println!(
            "  field {:<10} {:?}{:?} at byte {}, f32 slot {}",
            f.name, f.dtype, f.shape, f.byte_offset, f.f32_offset
        );
    }

    // Greedy compass-following policy through the *flat* interface —
    // exactly what a learner sees.
    let mut rng = Rng::new(1);
    let layout = venv.obs_layout().clone();
    let compass_slot = layout.field("compass").unwrap().f32_offset;
    let mut wins = 0;
    let mut games = 0;
    venv.async_reset(7);
    for _ in 0..600 {
        let (obs, actions) = {
            let b = venv.recv()?;
            let mut f32row = vec![0.0f32; layout.flat_len()];
            let mut acts = Vec::new();
            for row in b.obs.chunks_exact(layout.byte_len()) {
                layout.row_to_f32(row, &mut f32row);
                let compass = f32row[compass_slot] as i32;
                // compass encodes the direction of food: follow it (add
                // a little noise so episodes vary).
                let a = if rng.chance(0.1) {
                    rng.below(4) as i32
                } else {
                    match compass {
                        0 => 2, // food east -> move E
                        1 => 3,
                        2 => 1, // food south -> move S
                        _ => 0,
                    }
                };
                acts.push(a);
            }
            for (_, info) in &b.infos {
                for (k, v) in info {
                    if *k == "score" {
                        games += 1;
                        if *v > 0.5 {
                            wins += 1;
                        }
                    }
                }
            }
            (b.obs.len(), acts)
        };
        let _ = obs;
        venv.send(&actions)?;
    }
    println!("compass policy: {wins}/{games} episodes found the food");
    Ok(())
}
