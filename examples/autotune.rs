//! Run the vectorization autotuner (paper §3.3) on a profile environment:
//! benchmarks all four code paths plus serial across worker counts,
//! recommends the best configuration for this host, and emits it as a
//! machine-readable `VecSpec` — the exact value a RunSpec's
//! `vec = "auto"` consumes from the cache file.
//!
//! ```bash
//! cargo run --release --example autotune [env] [num_envs] [secs]
//! ```

use pufferlib::vector::autotune::{
    autotune, cache_path, format_results, trainable_winner, write_cache,
};
use pufferlib::wrappers::EnvSpec;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let env = args.first().cloned().unwrap_or_else(|| "profile/minigrid".into());
    let num_envs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let secs: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(0.5);

    println!("autotuning {env} ({num_envs} envs, {secs}s per candidate)\n");
    let spec = EnvSpec::new(env.as_str());
    let results = autotune(&spec, num_envs, 8, secs)?;
    print!("{}", format_results(&results));
    let best = &results[0];
    println!(
        "\nrecommended: {} → VecConfig {{ num_envs: {}, num_workers: {}, batch_size: {}, zero_copy: {} }}",
        best.label, best.cfg.num_envs, best.cfg.num_workers, best.cfg.batch_size, best.cfg.zero_copy
    );
    // The declarative form: serializable into a RunSpec [vec] section,
    // and cached where `vec = "auto"` looks for it. The cache only
    // accepts trainable (full/half batch) candidates — the policy
    // forward is compiled for exactly those shapes.
    let winner = trainable_winner(&results, num_envs).vec_spec();
    println!("vec spec: {}", winner.to_json().dump());
    let cache = cache_path(None);
    write_cache(&cache, &spec.key(), num_envs, &winner)?;
    println!("cached → {}", cache.display());
    Ok(())
}
