//! `cargo xtask lint` — repo-invariant checks that rustc/clippy cannot
//! express (see `CONCURRENCY.md` for the rationale behind each). Scans
//! every workspace crate: `crates/puffer-core/src`,
//! `crates/puffer-train/src`, and `crates/puffer-py/src`.
//!
//! - **R1 (ordering)**: every `Ordering::` use in the concurrency-
//!   bearing modules (`vector/`, `policy/`, `serve/` of any crate)
//!   carries a `// ordering:` comment on the same line or within 3
//!   lines above, naming the edge it establishes.
//! - **R2 (panic)**: no `.unwrap()` / `.expect(` in crate sources
//!   outside `#[cfg(test)]` blocks without a `// PANIC:` justification
//!   on the same line or within 3 lines above.
//! - **R3 (hot path)**: no allocation tokens inside `fn on_step` /
//!   `fn project_step` bodies in `wrappers/` — these run per step per
//!   env and must stay allocation-free.
//! - **R4 (forbid)**: modules that need no unsafe carry
//!   `#![forbid(unsafe_code)]`, keeping the unsafe surface pinned to
//!   puffer-core's `vector/`.
//! - **R5 (kernel alloc)**: `backend/kernels/` is a hot path end to
//!   end (serve forwards and train steps run through it every batch),
//!   so allocation tokens are banned file-wide there, not just inside
//!   named functions. Deliberate cold-path allocations carry an
//!   `// ALLOC-OK:` comment with a reason.
//!
//! Output is `file:line: RULE — message`, one finding per line; exit
//! status is nonzero when anything fires. CI runs this in the lint job;
//! locally it is `make lint` / `cargo xtask lint`.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// How many *non-comment* lines above a flagged line a justification
/// comment may sit — comment lines are traversed freely, so a
/// multi-line `// ordering:` / `// PANIC:` block directly above its
/// statement always counts, however long it is.
const MARKER_WINDOW: usize = 3;

/// Files that must stay `#![forbid(unsafe_code)]` (R4). Paths are
/// relative to the repo root. puffer-core's `vector/` is deliberately
/// absent — it owns the workspace's entire unsafe surface.
const FORBID_UNSAFE: &[&str] = &[
    "crates/puffer-core/src/backend.rs",
    "crates/puffer-core/src/config/mod.rs",
    "crates/puffer-core/src/emulation/mod.rs",
    "crates/puffer-core/src/envs/mod.rs",
    "crates/puffer-core/src/policy/mod.rs",
    "crates/puffer-core/src/runs.rs",
    "crates/puffer-core/src/runspec.rs",
    "crates/puffer-core/src/serve.rs",
    "crates/puffer-core/src/spaces/mod.rs",
    "crates/puffer-core/src/sync/mod.rs",
    "crates/puffer-core/src/train.rs",
    "crates/puffer-core/src/util/mod.rs",
    "crates/puffer-core/src/wrappers/mod.rs",
    "crates/puffer-py/src/bridge.rs",
    "crates/puffer-train/src/backend/kernels/mod.rs",
    "crates/puffer-train/src/policy/mod.rs",
    "crates/puffer-train/src/runs/mod.rs",
    "crates/puffer-train/src/runspec_ext.rs",
    "crates/puffer-train/src/serve/mod.rs",
    "crates/puffer-train/src/train/mod.rs",
];

/// Allocation tokens banned from wrapper hot paths (R3).
const ALLOC_TOKENS: &[&str] = &[
    "vec!",
    "Vec::new",
    "with_capacity",
    "to_vec(",
    "Box::new",
    "String::new",
    "String::from",
    "format!",
    ".collect()",
    ".clone()",
];

#[derive(Debug)]
struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {} — {}", self.file, self.line, self.rule, self.msg)
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(),
        _ => {
            eprintln!("usage: cargo xtask lint");
            ExitCode::from(2)
        }
    }
}

/// Crate source roots the lint walks, relative to the repo root.
const SRC_ROOTS: &[&str] = &[
    "crates/puffer-core/src",
    "crates/puffer-train/src",
    "crates/puffer-py/src",
];

fn lint() -> ExitCode {
    let root = repo_root();
    let mut findings = Vec::new();
    let mut scanned = 0usize;

    for src in SRC_ROOTS {
        for path in rust_files(&root.join(src)) {
            let rel = path
                .strip_prefix(&root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let text = match std::fs::read_to_string(&path) {
                Ok(t) => t,
                Err(e) => {
                    findings.push(Finding {
                        file: rel,
                        line: 0,
                        rule: "IO",
                        msg: format!("unreadable: {e}"),
                    });
                    continue;
                }
            };
            scanned += 1;
            if rel.contains("/src/vector/")
                || rel.contains("/src/policy/")
                || rel.contains("/src/serve/")
            {
                findings.extend(check_ordering(&rel, &text));
            }
            findings.extend(check_panics(&rel, &text));
            if rel.contains("/src/wrappers/") {
                findings.extend(check_hot_paths(&rel, &text));
            }
            if rel.contains("/src/backend/kernels/") {
                findings.extend(check_kernel_allocs(&rel, &text));
            }
        }
    }
    findings.extend(check_forbid(&root));

    if findings.is_empty() {
        println!(
            "xtask lint: {scanned} files clean (R1 ordering, R2 panic, R3 hot-path, R4 forbid, R5 kernel-alloc)"
        );
        ExitCode::SUCCESS
    } else {
        for f in &findings {
            println!("{f}");
        }
        println!("xtask lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}

fn repo_root() -> PathBuf {
    // xtask lives at <root>/xtask, so the repo root is one level up.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask has a parent directory")
        .to_path_buf()
}

/// All `.rs` files under `dir`, recursively, in stable order.
fn rust_files(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&d) else {
            continue;
        };
        for entry in entries.flatten() {
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    out
}

/// The code portion of a line: everything before a `//` comment. Naive
/// about `//` inside string literals, which only makes the checks more
/// conservative (tokens inside the false "comment" are not flagged).
fn code_part(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// Per-line mask: `true` for lines inside a `#[cfg(test)]` item body
/// (brace-tracked from the attribute's item). The attribute line itself
/// and everything through the item's closing brace are masked.
fn test_line_mask(lines: &[&str]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if lines[i].trim_start().starts_with("#[cfg(test)]") {
            let mut depth = 0i32;
            let mut entered = false;
            let mut j = i;
            while j < lines.len() {
                mask[j] = true;
                let code = code_part(lines[j]);
                depth += code.matches('{').count() as i32;
                depth -= code.matches('}').count() as i32;
                if code.contains('{') {
                    entered = true;
                }
                if entered && depth <= 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    mask
}

/// Does line `i` (0-based) or the span above it carry `marker`? Walking
/// upward, comment lines are free; at most `window` non-comment lines
/// (code, blanks) may be crossed before giving up. This lets a
/// justification block sit directly above a multi-line statement.
fn marker_nearby(lines: &[&str], i: usize, marker: &str, window: usize) -> bool {
    if lines[i].contains(marker) {
        return true;
    }
    let mut budget = window;
    let mut j = i;
    while j > 0 {
        j -= 1;
        if lines[j].contains(marker) {
            return true;
        }
        if !lines[j].trim_start().starts_with("//") {
            budget -= 1;
            if budget == 0 {
                return false;
            }
        }
    }
    false
}

/// R1: `Ordering::` uses in concurrency-bearing modules must say which
/// happens-before edge they establish (or why none is needed).
fn check_ordering(rel: &str, text: &str) -> Vec<Finding> {
    let lines: Vec<&str> = text.lines().collect();
    let mask = test_line_mask(&lines);
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if mask[i] || !code_part(line).contains("Ordering::") {
            continue;
        }
        if !marker_nearby(&lines, i, "// ordering:", MARKER_WINDOW) {
            out.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: "R1",
                msg: "atomic Ordering without a `// ordering:` comment naming its edge".into(),
            });
        }
    }
    out
}

/// R2: `.unwrap()` / `.expect(` outside tests must justify why the
/// panic is unreachable (or deliberate) with `// PANIC:`.
fn check_panics(rel: &str, text: &str) -> Vec<Finding> {
    let lines: Vec<&str> = text.lines().collect();
    let mask = test_line_mask(&lines);
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if mask[i] {
            continue;
        }
        let code = code_part(line);
        if !code.contains(".unwrap()") && !code.contains(".expect(") {
            continue;
        }
        if !marker_nearby(&lines, i, "// PANIC:", MARKER_WINDOW) {
            out.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: "R2",
                msg: "unwrap/expect outside tests without a `// PANIC:` justification".into(),
            });
        }
    }
    out
}

/// R3: wrapper hot paths (`on_step` / `project_step`) run once per step
/// per env — allocation there silently wrecks the throughput the
/// vectorization layer exists to provide.
fn check_hot_paths(rel: &str, text: &str) -> Vec<Finding> {
    let lines: Vec<&str> = text.lines().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        let code = code_part(lines[i]);
        if !(code.contains("fn on_step") || code.contains("fn project_step")) {
            i += 1;
            continue;
        }
        // Walk the body: from the signature to its balancing brace.
        let mut depth = 0i32;
        let mut entered = false;
        let mut j = i;
        while j < lines.len() {
            let body = code_part(lines[j]);
            depth += body.matches('{').count() as i32;
            depth -= body.matches('}').count() as i32;
            if body.contains('{') {
                entered = true;
            }
            for tok in ALLOC_TOKENS {
                if body.contains(tok) {
                    out.push(Finding {
                        file: rel.to_string(),
                        line: j + 1,
                        rule: "R3",
                        msg: format!("allocation token `{tok}` in a per-step hot path"),
                    });
                }
            }
            if entered && depth <= 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    out
}

/// R5: kernel files are steady-state hot paths end to end — the serve
/// batcher and the trainer's minibatch loop call into them every batch
/// through preallocated scratch, so the whole file must stay
/// allocation-free. A deliberate cold-path allocation (construction,
/// error paths) is waived line-by-line with `// ALLOC-OK: <reason>`.
fn check_kernel_allocs(rel: &str, text: &str) -> Vec<Finding> {
    let lines: Vec<&str> = text.lines().collect();
    let mask = test_line_mask(&lines);
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if mask[i] {
            continue;
        }
        let code = code_part(line);
        for tok in ALLOC_TOKENS {
            if code.contains(tok) && !marker_nearby(&lines, i, "// ALLOC-OK:", MARKER_WINDOW) {
                out.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "R5",
                    msg: format!(
                        "allocation token `{tok}` in kernel code (waive with `// ALLOC-OK: <reason>` if cold-path)"
                    ),
                });
            }
        }
    }
    out
}

/// R4: the forbid list keeps the unsafe surface pinned to `vector/`.
fn check_forbid(root: &Path) -> Vec<Finding> {
    let mut out = Vec::new();
    for rel in FORBID_UNSAFE {
        let path = root.join(rel);
        let ok = std::fs::read_to_string(&path)
            .map(|t| t.contains("#![forbid(unsafe_code)]"))
            .unwrap_or(false);
        if !ok {
            out.push(Finding {
                file: (*rel).to_string(),
                line: 1,
                rule: "R4",
                msg: "missing `#![forbid(unsafe_code)]` (or file unreadable)".into(),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_mask_covers_cfg_test_modules() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn b() {}\n";
        let lines: Vec<&str> = src.lines().collect();
        let mask = test_line_mask(&lines);
        assert_eq!(mask, vec![false, true, true, true, true, false]);
    }

    #[test]
    fn ordering_needs_a_comment() {
        let bad = "let x = f.load(Ordering::Acquire);\n";
        assert_eq!(check_ordering("f.rs", bad).len(), 1);
        let same_line = "let x = f.load(Ordering::Acquire); // ordering: pairs with store\n";
        assert!(check_ordering("f.rs", same_line).is_empty());
        let above = "// ordering: Acquire pairs with the worker's Release\nlet x = f.load(Ordering::Acquire);\n";
        assert!(check_ordering("f.rs", above).is_empty());
        let too_far =
            "// ordering: far away\n\n\n\n\nlet x = f.load(Ordering::Acquire);\n";
        assert_eq!(check_ordering("f.rs", too_far).len(), 1);
    }

    #[test]
    fn ordering_in_tests_and_comments_is_exempt() {
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t() { f.load(Ordering::SeqCst); }\n}\n";
        assert!(check_ordering("f.rs", in_test).is_empty());
        let in_comment = "// uses Ordering::Acquire internally\nfn a() {}\n";
        assert!(check_ordering("f.rs", in_comment).is_empty());
    }

    #[test]
    fn unwrap_needs_a_panic_comment() {
        let bad = "let v = x.unwrap();\n";
        assert_eq!(check_panics("f.rs", bad).len(), 1);
        let ok = "// PANIC: x was checked two lines up\nlet v = x.unwrap();\n";
        assert!(check_panics("f.rs", ok).is_empty());
        // unwrap_or / expect_byte style names never match.
        let cousins = "let v = x.unwrap_or(0);\nlet b = p.expect_byte(b'x');\n";
        assert!(check_panics("f.rs", cousins).is_empty());
        // Test code is exempt.
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        assert!(check_panics("f.rs", in_test).is_empty());
    }

    #[test]
    fn hot_path_allocation_is_flagged() {
        let bad = "fn on_step(&mut self) {\n    let v = vec![0.0; 4];\n}\n";
        let f = check_hot_paths("w.rs", bad);
        assert_eq!(f.len(), 1);
        assert!(f[0].msg.contains("vec!"));
        // Allocation outside the hot path is fine.
        let ok = "fn reset(&mut self) {\n    let v = vec![0.0; 4];\n}\nfn on_step(&mut self) {\n    self.t += 1;\n}\n";
        assert!(check_hot_paths("w.rs", ok).is_empty());
        // project_step is covered too.
        let proj = "fn project_step(&self) {\n    let s = String::new();\n}\n";
        assert_eq!(check_hot_paths("w.rs", proj).len(), 1);
    }

    #[test]
    fn kernel_allocs_are_flagged_file_wide() {
        // Outside any named hot-path function — still flagged in kernels.
        let bad = "pub fn helper() -> Vec<f32> {\n    let v = vec![0.0; 4];\n    v\n}\n";
        let f = check_kernel_allocs("k.rs", bad);
        assert_eq!(f.len(), 1);
        assert!(f[0].msg.contains("vec!"));
        // ALLOC-OK on the same line or just above waives it.
        let waived_same =
            "let v = vec![0.0; 4]; // ALLOC-OK: one-time construction\n";
        assert!(check_kernel_allocs("k.rs", waived_same).is_empty());
        let waived_above =
            "// ALLOC-OK: config-parse error path, not kernel code.\nlet e = format!(\"bad {x}\");\n";
        assert!(check_kernel_allocs("k.rs", waived_above).is_empty());
        // Tokens in comments and test modules are exempt.
        let in_comment = "// callers pass Vec::new() scratch\nfn f() {}\n";
        assert!(check_kernel_allocs("k.rs", in_comment).is_empty());
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t() { let v = vec![1]; }\n}\n";
        assert!(check_kernel_allocs("k.rs", in_test).is_empty());
    }
}
